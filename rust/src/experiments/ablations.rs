//! Ablation studies over HERMES's own design choices (DESIGN.md §6),
//! driven by `scenarios/ablations.json`:
//!
//!  A. routing policy — the paper's "up to nine distinct routing
//!     strategies" (§III-B.1): RR vs load-based × metric vs heavy-light,
//!     on a skewed (code) trace where balance matters;
//!  B. KV-transfer granularity — full-cache vs layerwise hand-off in
//!     disaggregated serving (§III-B.2 / Splitwise);
//!  C. packing policy — FCFS vs Least-Work-Left under bursty arrivals.

use anyhow::{Context, Result};

use crate::config::{self, slo::SloLadder};
use crate::scenario::Scenario;
use crate::sim::builder::{NetSpec, PoolSpec, ServingSpec};
use crate::sim::driver;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::rng::Arrival;
use crate::workload::trace::{TraceKind, WorkloadSpec};

pub fn run(fast: bool) -> Result<()> {
    let sc = Scenario::load("ablations")?;
    let ex = sc.extras();
    let use_fast = sc.use_fast(fast);
    routing(&sc, ex.get("routing").context("ablations extras.routing")?, use_fast)?;
    granularity(&sc, ex.get("granularity").context("ablations extras.granularity")?, use_fast)?;
    packing(&sc, ex.get("packing").context("ablations extras.packing")?, use_fast)?;
    Ok(())
}

/// Read the `<key>_fast` / `<key>_full` variant for this run; missing
/// keys are an error so a full run can never silently use toy scale.
fn n_of(j: &Json, use_fast: bool, key: &str) -> Result<usize> {
    let k = format!("{key}_{}", if use_fast { "fast" } else { "full" });
    j.get(&k)
        .and_then(Json::as_usize)
        .with_context(|| format!("ablations scenario needs {k}"))
}

fn routing(sc: &Scenario, j: &Json, use_fast: bool) -> Result<()> {
    let n_req = n_of(j, use_fast, "n_requests")?;
    let clients = n_of(j, use_fast, "clients")?;
    let rate = j.f64_or("rate_per_client", 1.5);
    let seed = j.f64_or("seed", 31.0) as u64;
    println!("\nA. Routing policies (code trace — long, highly variable prompts)");
    let mut t = Table::new(&["policy", "ttft_p50(ms)", "ttft_p99(ms)", "e2e_p99(s)", "thr tok/s"]);
    let slo = SloLadder::standard();
    let policies = j
        .get("policies")
        .and_then(Json::as_arr)
        .context("routing ablation needs 'policies'")?;
    for p in policies {
        let name = p.as_str().context("policy entries are strings")?;
        let mut spec = sc.serving(&sc.roster[0], clients)?;
        spec.route = config::parse_router(name)?;
        let w = WorkloadSpec::new(spec.model, TraceKind::AzureCode, n_req, clients as f64 * rate)
            .with_seed(seed);
        let m = driver::run(&spec, &w, &slo)?;
        t.row(&[
            name.to_string(),
            format!("{:.0}", m.ttft.p50 * 1e3),
            format!("{:.0}", m.ttft.p99 * 1e3),
            format!("{:.2}", m.e2e.p99),
            format!("{:.0}", m.throughput_tok_s),
        ]);
    }
    t.print();
    Ok(())
}

fn granularity(sc: &Scenario, j: &Json, use_fast: bool) -> Result<()> {
    let n_req = n_of(j, use_fast, "n_requests")?;
    let seed = j.f64_or("seed", 32.0) as u64;
    // Bloom-176B's MHA KV (~3.8 MB/token) makes the prefill→decode
    // hand-off a multi-GB transfer — exactly the case layerwise
    // streaming (Splitwise §4) was designed for. TTFT is unaffected
    // (the first token is emitted before the hand-off); the exposed
    // transfer delays the SECOND token, i.e. TPOT and e2e.
    println!("\nB. KV-transfer granularity, disaggregated Bloom-176B (MHA: huge KV hand-offs)");
    let mut t = Table::new(&[
        "granularity", "tpot_p99(ms)", "e2e_p50(s)", "e2e_p99(s)", "exposed transfer s/req",
    ]);
    let slo = SloLadder::standard();
    let model = crate::hardware::model(j.str_or("model", "bloom-176b"))
        .context("granularity ablation model")?
        .name;
    let prefill = j.usize_or("prefill", 4);
    let decode = j.usize_or("decode", 2);
    let options = j
        .get("options")
        .and_then(Json::as_arr)
        .context("granularity ablation needs 'options'")?;
    for g in options {
        let name = g.as_str().context("granularity entries are strings")?;
        let mut spec = ServingSpec::new(
            model,
            crate::hardware::npu(sc.doc.str_or("npu", "h100")).context("npu")?,
            j.usize_or("tp", 8),
            PoolSpec::Disaggregated { prefill, decode, local: false },
        );
        spec.perf = config::parse_perf_backend(sc.doc.str_or("perf_model", "poly"))?;
        spec.net = NetSpec::Hierarchy {
            per_platform: j.usize_or("per_platform", 2),
            per_rack: j.usize_or("per_rack", 6),
        };
        spec.granularity = config::parse_granularity(name)?;
        let w = WorkloadSpec::new(model, TraceKind::AzureConv, n_req, j.f64_or("rate", 10.0))
            .with_seed(seed);
        let m = driver::run(&spec, &w, &slo)?;
        t.row(&[
            name.to_string(),
            format!("{:.1}", m.tpot.p99 * 1e3),
            format!("{:.2}", m.e2e.p50),
            format!("{:.2}", m.e2e.p99),
            format!("{:.3}", m.transfer_seconds / m.n_serviced.max(1) as f64),
        ]);
    }
    t.print();
    Ok(())
}

fn packing(sc: &Scenario, j: &Json, use_fast: bool) -> Result<()> {
    let n_req = n_of(j, use_fast, "n_requests")?;
    let clients = j.usize_or("clients", 2);
    let rate = j.f64_or("rate", 3.0);
    let seed = j.f64_or("seed", 33.0) as u64;
    println!("\nC. Packing policy under bursty arrivals (LWL favors short requests)");
    let mut t = Table::new(&["packing", "ttft_p50(ms)", "ttft_p99(ms)", "e2e_p50(s)", "e2e_p99(s)"]);
    let slo = SloLadder::standard();
    let options = j
        .get("options")
        .and_then(Json::as_arr)
        .context("packing ablation needs 'options'")?;
    for p in options {
        let name = p.as_str().context("packing entries are strings")?;
        let mut spec = sc.serving(&sc.roster[0], clients)?;
        spec.packing = config::parse_packing(name)?;
        spec.sched.max_batch_seqs = j.usize_or("max_batch_seqs", 64);
        let w = WorkloadSpec::new(spec.model, TraceKind::AzureCode, n_req, rate)
            .with_arrival(Arrival::Bursty {
                rate,
                burst_mult: j.f64_or("burst_mult", 6.0),
                calm_s: j.f64_or("calm_s", 10.0),
                burst_s: j.f64_or("burst_s", 2.0),
            })
            .with_seed(seed);
        let m = driver::run(&spec, &w, &slo)?;
        t.row(&[
            name.to_string(),
            format!("{:.0}", m.ttft.p50 * 1e3),
            format!("{:.0}", m.ttft.p99 * 1e3),
            format!("{:.2}", m.e2e.p50),
            format!("{:.2}", m.e2e.p99),
        ]);
    }
    t.print();
    Ok(())
}
