//! Ablation studies over HERMES's own design choices (DESIGN.md §6):
//!
//!  A. routing policy — the paper's "up to nine distinct routing
//!     strategies" (§III-B.1): RR vs load-based × metric vs heavy-light,
//!     on a skewed (code) trace where balance matters;
//!  B. KV-transfer granularity — full-cache vs layerwise hand-off in
//!     disaggregated serving (§III-B.2 / Splitwise);
//!  C. packing policy — FCFS vs Least-Work-Left under bursty arrivals.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::coordinator::{LoadMetric, RoutePolicy};
use crate::hardware::npu::H100;
use crate::network::Granularity;
use crate::scheduler::{BatchingKind, Packing, SchedConfig};
use crate::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use crate::sim::driver;
use crate::util::bench::Table;
use crate::workload::trace::{TraceKind, WorkloadSpec};

pub fn run(fast: bool) -> Result<()> {
    routing(fast)?;
    granularity(fast)?;
    packing(fast)?;
    Ok(())
}

fn routing(fast: bool) -> Result<()> {
    let (n_req, clients) = if fast { (160, 4) } else { (960, 8) };
    println!("\nA. Routing policies (code trace — long, highly variable prompts)");
    let mut t = Table::new(&["policy", "ttft_p50(ms)", "ttft_p99(ms)", "e2e_p99(s)", "thr tok/s"]);
    let policies: Vec<(&str, RoutePolicy)> = vec![
        ("round-robin", RoutePolicy::RoundRobin),
        ("load:input-len", RoutePolicy::LoadBased(LoadMetric::InputLen)),
        ("load:output-len", RoutePolicy::LoadBased(LoadMetric::OutputLen)),
        ("load:kv-size", RoutePolicy::LoadBased(LoadMetric::KvSize)),
        ("load:tokens-left", RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
        (
            "heavy-light",
            RoutePolicy::HeavyLight {
                metric: LoadMetric::TokensLeft,
                threshold_tokens: 2048,
                heavy_frac: 0.5,
            },
        ),
    ];
    let slo = SloLadder::standard();
    for (name, policy) in policies {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            2,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: clients },
        )
        .with_perf(PerfBackend::Poly)
        .with_route(policy);
        let w = WorkloadSpec::new("llama3-70b", TraceKind::AzureCode, n_req, clients as f64 * 1.5)
            .with_seed(31);
        let m = driver::run(&spec, &w, &slo)?;
        t.row(&[
            name.to_string(),
            format!("{:.0}", m.ttft.p50 * 1e3),
            format!("{:.0}", m.ttft.p99 * 1e3),
            format!("{:.2}", m.e2e.p99),
            format!("{:.0}", m.throughput_tok_s),
        ]);
    }
    t.print();
    Ok(())
}

fn granularity(fast: bool) -> Result<()> {
    let n_req = if fast { 150 } else { 600 };
    // Bloom-176B's MHA KV (~3.8 MB/token) makes the prefill→decode
    // hand-off a multi-GB transfer — exactly the case layerwise
    // streaming (Splitwise §4) was designed for. TTFT is unaffected
    // (the first token is emitted before the hand-off); the exposed
    // transfer delays the SECOND token, i.e. TPOT and e2e.
    println!("\nB. KV-transfer granularity, disaggregated Bloom-176B (MHA: huge KV hand-offs)");
    let mut t = Table::new(&[
        "granularity", "tpot_p99(ms)", "e2e_p50(s)", "e2e_p99(s)", "exposed transfer s/req",
    ]);
    let slo = SloLadder::standard();
    for (name, gran) in [
        ("full-cache", Granularity::Full),
        ("layerwise(70)", Granularity::Layerwise { layers: 70 }),
    ] {
        let mut spec = ServingSpec::new(
            "bloom-176b",
            H100,
            8,
            PoolSpec::Disaggregated { prefill: 4, decode: 2, local: false },
        )
        .with_perf(PerfBackend::Poly)
        .with_net(crate::sim::builder::NetSpec::Hierarchy { per_platform: 2, per_rack: 6 });
        spec.granularity = gran;
        let w = WorkloadSpec::new("bloom-176b", TraceKind::AzureConv, n_req, 10.0).with_seed(32);
        let m = driver::run(&spec, &w, &slo)?;
        t.row(&[
            name.to_string(),
            format!("{:.1}", m.tpot.p99 * 1e3),
            format!("{:.2}", m.e2e.p50),
            format!("{:.2}", m.e2e.p99),
            format!("{:.3}", m.transfer_seconds / m.n_serviced.max(1) as f64),
        ]);
    }
    t.print();
    Ok(())
}

fn packing(fast: bool) -> Result<()> {
    let n_req = if fast { 200 } else { 800 };
    println!("\nC. Packing policy under bursty arrivals (LWL favors short requests)");
    let mut t = Table::new(&["packing", "ttft_p50(ms)", "ttft_p99(ms)", "e2e_p50(s)", "e2e_p99(s)"]);
    let slo = SloLadder::standard();
    for (name, packing) in [("fcfs", Packing::Fcfs), ("least-work-left", Packing::LeastWorkLeft)] {
        let mut spec = ServingSpec::new(
            "llama3-70b",
            H100,
            2,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        )
        .with_perf(PerfBackend::Poly);
        spec.packing = packing;
        spec.sched = SchedConfig { max_batch_seqs: 64, max_batch_tokens: 8192 };
        let w = WorkloadSpec::new("llama3-70b", TraceKind::AzureCode, n_req, 3.0)
            .with_arrival(crate::util::rng::Arrival::Bursty {
                rate: 3.0,
                burst_mult: 6.0,
                calm_s: 10.0,
                burst_s: 2.0,
            })
            .with_seed(33);
        let m = driver::run(&spec, &w, &slo)?;
        t.row(&[
            name.to_string(),
            format!("{:.0}", m.ttft.p50 * 1e3),
            format!("{:.0}", m.ttft.p99 * 1e3),
            format!("{:.2}", m.e2e.p50),
            format!("{:.2}", m.e2e.p99),
        ]);
    }
    t.print();
    Ok(())
}
