//! Fig 6 — fidelity of the ML-predicted runtime path vs the fine-grained
//! hardware model.
//!
//! Configuration lives in `scenarios/fig6.json`: Llama-3.1-70B on
//! HGX-H100×8 with vLLM chunked batching, varying context length,
//! request count and chunk size across TP2/4/8, 200 output tokens;
//! HERMES achieves <2% average end-to-end error. Our "measured" side is
//! the roofline oracle the regression was fitted on (DESIGN.md §3): the
//! figure quantifies how much fidelity the fitted-polynomial fast path
//! loses end-to-end.

use anyhow::{Context, Result};

use crate::config::slo::SloLadder;
use crate::scenario::Scenario;
use crate::scheduler::BatchingKind;
use crate::sim::builder::{PerfBackend, PoolSpec};
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::trace::{TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub tp: usize,
    pub ctx: f64,
    pub n_req: usize,
    pub chunk: usize,
    pub predicted_s: f64,
    pub oracle_s: f64,
    pub err_pct: f64,
}

pub fn run(fast: bool) -> Result<Vec<Fig6Row>> {
    let sc = Scenario::load("fig6")?;
    let tps = sc.extra_usize_list(&sc.scaled_key(fast, "tps"))?;
    let ctxs = sc.extra_f64_list(&sc.scaled_key(fast, "ctxs"))?;
    let nreqs = sc.extra_usize_list(&sc.scaled_key(fast, "nreqs"))?;
    let chunks = sc.extra_usize_list(&sc.scaled_key(fast, "chunks"))?;
    let ctx_std_frac = sc.extras().f64_or("ctx_std_frac", 0.1);
    let model: &'static str = crate::hardware::model(sc.doc.str_or("model", "llama3-70b"))
        .context("fig6 scenario model")?
        .name;
    let base_workload = sc.doc.get("workload").cloned().unwrap_or_else(Json::obj);
    let out_mean = base_workload.f64_or("out_mean", 200.0);
    let rate = base_workload.f64_or("rate", 8.0);
    let seed = sc.doc.f64_or("seed", 6.0) as u64;

    let mut rows = Vec::new();
    for &tp in &tps {
        for &ctx in &ctxs {
            for &n in &nreqs {
                for &chunk in &chunks {
                    let workload = WorkloadSpec::new(
                        model,
                        TraceKind::Synthetic {
                            in_mean: ctx,
                            in_std: ctx * ctx_std_frac,
                            out_mean, // paper: 200 output tokens
                            out_std: 1.0,
                        },
                        n,
                        rate,
                    )
                    .with_seed(seed);
                    let run_one = |perf: PerfBackend| -> Result<crate::metrics::RunMetrics> {
                        let mut spec = sc.serving(&sc.roster[0], 1)?;
                        spec.tp = tp;
                        spec.pool = PoolSpec::Combined {
                            kind: BatchingKind::Chunked { chunk },
                            n: 1,
                        };
                        spec.perf = perf;
                        crate::sim::driver::run(&spec, &workload, &SloLadder::standard())
                    };
                    let pred = run_one(PerfBackend::Poly)?;
                    let oracle = run_one(PerfBackend::Roofline)?;
                    rows.push(Fig6Row {
                        tp,
                        ctx,
                        n_req: n,
                        chunk,
                        predicted_s: pred.makespan,
                        oracle_s: oracle.makespan,
                        err_pct: (pred.makespan - oracle.makespan).abs() / oracle.makespan * 100.0,
                    });
                }
            }
        }
    }
    let mut t = Table::new(&["tp", "ctx", "reqs", "chunk", "predicted(s)", "oracle(s)", "err %"]);
    for r in &rows {
        t.row(&[
            format!("{}", r.tp),
            format!("{:.0}", r.ctx),
            format!("{}", r.n_req),
            format!("{}", r.chunk),
            format!("{:.3}", r.predicted_s),
            format!("{:.3}", r.oracle_s),
            format!("{:.2}", r.err_pct),
        ]);
    }
    t.print();
    let errs: Vec<f64> = rows.iter().map(|r| r.err_pct).collect();
    println!(
        "avg error {:.2}%  max {:.2}%  (paper: <2% average)",
        stats::mean(&errs),
        errs.iter().fold(0.0f64, |a, &b| a.max(b))
    );
    Ok(rows)
}
