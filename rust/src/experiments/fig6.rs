//! Fig 6 — fidelity of the ML-predicted runtime path vs the fine-grained
//! hardware model.
//!
//! Paper setup: Llama-3.1-70B on HGX-H100×8 with vLLM chunked batching,
//! varying context length, request count and chunk size across TP2/4/8,
//! 200 output tokens; HERMES achieves <2% average end-to-end error. Our
//! "measured" side is the roofline oracle the regression was fitted on
//! (DESIGN.md §3): the figure quantifies how much fidelity the
//! fitted-polynomial fast path loses end-to-end.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::hardware::npu::H100;
use crate::scheduler::BatchingKind;
use crate::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use crate::util::bench::Table;
use crate::util::stats;
use crate::workload::trace::{TraceKind, WorkloadSpec};

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub tp: usize,
    pub ctx: f64,
    pub n_req: usize,
    pub chunk: usize,
    pub predicted_s: f64,
    pub oracle_s: f64,
    pub err_pct: f64,
}

pub fn run(fast: bool) -> Result<Vec<Fig6Row>> {
    let tps: &[usize] = if fast { &[8] } else { &[2, 4, 8] };
    let ctxs: &[f64] = if fast { &[1024.0, 4096.0] } else { &[1024.0, 2048.0, 4096.0] };
    let nreqs: &[usize] = if fast { &[16] } else { &[8, 16, 32] };
    let chunks: &[usize] = if fast { &[512] } else { &[512, 1024, 2048] };

    let mut rows = Vec::new();
    for &tp in tps {
        for &ctx in ctxs {
            for &n in nreqs {
                for &chunk in chunks {
                    let workload = WorkloadSpec::new(
                        "llama3-70b",
                        TraceKind::Synthetic {
                            in_mean: ctx,
                            in_std: ctx * 0.1,
                            out_mean: 200.0, // paper: 200 output tokens
                            out_std: 1.0,
                        },
                        n,
                        8.0,
                    )
                    .with_seed(6);
                    let run_one = |perf: PerfBackend| {
                        let spec = ServingSpec::new(
                            "llama3-70b",
                            H100,
                            tp,
                            PoolSpec::Combined { kind: BatchingKind::Chunked { chunk }, n: 1 },
                        )
                        .with_perf(perf);
                        crate::sim::driver::run(&spec, &workload, &SloLadder::standard())
                    };
                    let pred = run_one(PerfBackend::Poly)?;
                    let oracle = run_one(PerfBackend::Roofline)?;
                    rows.push(Fig6Row {
                        tp,
                        ctx,
                        n_req: n,
                        chunk,
                        predicted_s: pred.makespan,
                        oracle_s: oracle.makespan,
                        err_pct: (pred.makespan - oracle.makespan).abs() / oracle.makespan * 100.0,
                    });
                }
            }
        }
    }
    let mut t = Table::new(&["tp", "ctx", "reqs", "chunk", "predicted(s)", "oracle(s)", "err %"]);
    for r in &rows {
        t.row(&[
            format!("{}", r.tp),
            format!("{:.0}", r.ctx),
            format!("{}", r.n_req),
            format!("{}", r.chunk),
            format!("{:.3}", r.predicted_s),
            format!("{:.3}", r.oracle_s),
            format!("{:.2}", r.err_pct),
        ]);
    }
    t.print();
    let errs: Vec<f64> = rows.iter().map(|r| r.err_pct).collect();
    println!(
        "avg error {:.2}%  max {:.2}%  (paper: <2% average)",
        stats::mean(&errs),
        errs.iter().fold(0.0f64, |a, &b| a.max(b))
    );
    Ok(rows)
}
