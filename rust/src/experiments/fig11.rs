//! Fig 11 — batching strategies with a RAG stage (§V-A.1).
//!
//! Configuration lives in `scenarios/fig11.json`: 6 docs × 500 tokens
//! add ~3K retrieval tokens to every prompt, RAG clients run E5-Base on
//! A100 with Grace-class retrieval, and the RAG-pipeline SLO ladder
//! (TTFT base 1000 ms) applies.
//!
//! Expected shape: lower sustainable injection rates than Fig 10;
//! chunked/disaggregated top throughput, disaggregated best energy.

use anyhow::Result;

use crate::experiments::fig10::{self, Fig10Result};
use crate::scenario::Scenario;

pub fn run(fast: bool) -> Result<Vec<Fig10Result>> {
    let sc = Scenario::load("fig11")?;
    fig10::run_scenario(fast, &sc, "Fig 11 (RAG)")
}
