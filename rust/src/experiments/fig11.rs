//! Fig 11 — batching strategies with a RAG stage (§V-A.1).
//!
//! "Including a RAG stage introduces 3K additional retrieval tokens,
//! extending prefill duration" → 6 docs × 500 tokens; RAG clients run
//! E5-Base on A100 with Grace-class retrieval. The RAG-pipeline SLO
//! ladder (TTFT base 1000 ms) applies.
//!
//! Expected shape: lower sustainable injection rates than Fig 10;
//! chunked/disaggregated top throughput, disaggregated best energy.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::experiments::fig10::{self, Fig10Result};
use crate::workload::request::RagParams;
use crate::workload::trace::Pipeline;

pub fn run(fast: bool) -> Result<Vec<Fig10Result>> {
    let rag = RagParams {
        query_tokens: 128,
        docs: 6,
        doc_tokens: 500, // 3K retrieval tokens (§V-A.1)
        ..Default::default()
    };
    fig10::run_pipeline(fast, Pipeline::Rag(rag), "Fig 11 (RAG)", &SloLadder::retrieval())
}
