//! Fig 9 — RAG pipeline bottlenecks across embedding-model placements
//! (§IV-B).
//!
//! Configuration lives in `scenarios/fig9.json` (`extras`): three
//! hardware placements — 1) Large CPU (Grace-like) embeds + retrieves,
//! 2) Small CPU (Sapphire-Rapids-like) embeds + retrieves, 3) A100
//! embeds + Large CPU retrieves — two embedding models (E5-Base,
//! Mistral-7B), prefill/decode on one H100 with Llama-3.1-8B, IVF-PQ at
//! 4M centroids / 50 probes / 5K points per probe, 20 docs × 512 tokens
//! (+10K context), retrieval→prefill link = PCIe4.0×4 (32 GB/s).
//!
//! Expected: Mistral-7B on the small CPU is a severe TTFT bottleneck;
//! offloading the embedder to the A100 collapses it; context transfer is
//! <1% of runtime even on PCIe.

use anyhow::{Context, Result};

use crate::hardware::roofline::{LlmCluster, PrefillItem};
use crate::hardware::{model, npu};
use crate::rag::ivfpq::{IvfPq, IvfPqConfig};
use crate::rag::RagEngine;
use crate::scenario::Scenario;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::request::RagParams;

#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub embed_model: String,
    pub hw: String,
    pub embed_s: f64,
    pub retrieve_s: f64,
    pub rerank_s: f64,
    pub transfer_s: f64,
    pub prefill_s: f64,
    pub ttft_s: f64,
    pub transfer_pct: f64,
}

pub fn run(_fast: bool) -> Result<Vec<Fig9Row>> {
    let sc = Scenario::load("fig9")?;
    let ex = sc.extras();
    let rag = ex.get("rag").cloned().unwrap_or_else(Json::obj);
    let params = RagParams {
        query_tokens: rag.usize_or("query_tokens", 128),
        docs: rag.usize_or("docs", 20),
        doc_tokens: rag.usize_or("doc_tokens", 512),
        centroids: rag.f64_or("centroids", 4e6),
        nprobe: rag.usize_or("nprobe", 50),
        points_per_probe: rag.usize_or("points_per_probe", 5000),
    };
    let link_bw = ex.f64_or("link_bw", 32e9); // B/s — retrieval→prefill link
    let link_lat = ex.f64_or("link_lat", 1e-5);
    let llm_model = model(sc.doc.str_or("model", "llama3-8b")).context("fig9 llm model")?;
    let llm_npu = npu(sc.doc.str_or("npu", "h100")).context("fig9 llm npu")?;
    let llm = LlmCluster::new(llm_model, llm_npu, sc.doc.usize_or("tp", 1));

    let embed_models: Vec<String> = ex
        .get("embed_models")
        .and_then(Json::as_arr)
        .map(|a| a.iter().filter_map(Json::as_str).map(str::to_string).collect())
        .unwrap_or_else(|| vec!["e5-base".into(), "mistral-7b".into()]);
    let placements: Vec<Json> = ex
        .get("placements")
        .and_then(Json::as_arr)
        .context("fig9 scenario needs extras.placements")?
        .to_vec();

    let mut rows = Vec::new();
    for embed_model in &embed_models {
        let emodel = model(embed_model)
            .with_context(|| format!("unknown embed model {embed_model}"))?;
        for placement in &placements {
            let hw = placement.str_or("label", "?").to_string();
            let embed_npu = npu(placement.str_or("embed_npu", "grace-cpu"))
                .context("placement embed_npu")?;
            let retr_npu = npu(placement.str_or("retrieval_npu", "grace-cpu"))
                .context("placement retrieval_npu")?;
            let engine = RagEngine::new(
                LlmCluster::new(emodel.clone(), embed_npu, 1),
                IvfPq::new(retr_npu, IvfPqConfig::default()),
            );
            let t = engine.batch_timing(1, &params);
            // retrieved context text moves to the prefill client over PCIe
            let ctx_tokens = params.context_tokens() as f64;
            let transfer_s = ctx_tokens * 4.0 / link_bw + link_lat;
            // prefill of query + retrieved context on the H100
            let prefill_s = llm.prefill_time(&[PrefillItem {
                past: 0.0,
                new: params.query_tokens as f64 + ctx_tokens,
            }]);
            let ttft = t.total() + transfer_s + prefill_s;
            rows.push(Fig9Row {
                embed_model: embed_model.clone(),
                hw,
                embed_s: t.embed_s,
                retrieve_s: t.retrieve_s,
                rerank_s: t.rerank_s,
                transfer_s,
                prefill_s,
                ttft_s: ttft,
                transfer_pct: transfer_s / ttft * 100.0,
            });
        }
    }
    let mut t = Table::new(&[
        "embed", "hardware", "embed(ms)", "retrieve(ms)", "rerank(ms)", "transfer(ms)",
        "prefill(ms)", "TTFT(ms)", "transfer %",
    ]);
    for r in &rows {
        t.row(&[
            r.embed_model.clone(),
            r.hw.clone(),
            format!("{:.1}", r.embed_s * 1e3),
            format!("{:.1}", r.retrieve_s * 1e3),
            format!("{:.2}", r.rerank_s * 1e3),
            format!("{:.3}", r.transfer_s * 1e3),
            format!("{:.1}", r.prefill_s * 1e3),
            format!("{:.1}", r.ttft_s * 1e3),
            format!("{:.2}", r.transfer_pct),
        ]);
    }
    t.print();
    println!("expected shape: mistral-7b@small-cpu dominated by embedding;");
    println!("offload to A100 collapses it; transfer <1% of TTFT everywhere.");
    Ok(rows)
}
