//! Fig 9 — RAG pipeline bottlenecks across embedding-model placements
//! (§IV-B).
//!
//! Three hardware configurations: 1) Large CPU (Grace-like) embeds +
//! retrieves, 2) Small CPU (Sapphire-Rapids-like) embeds + retrieves,
//! 3) A100 embeds + Large CPU retrieves. Two embedding models (E5-Base,
//! Mistral-7B). Prefill/decode on one H100 with Llama-3.1-8B. IVF-PQ:
//! 4M centroids, 50 probes, 5K points/probe; 20 docs × 512 tokens → +10K
//! context tokens; retrieval→prefill link = PCIe4.0×4 (32 GB/s).
//!
//! Expected: Mistral-7B on the small CPU is a severe TTFT bottleneck;
//! offloading the embedder to the A100 collapses it; context transfer is
//! <1% of runtime even on PCIe.

use anyhow::Result;

use crate::hardware::models::{E5_BASE, LLAMA3_8B, MISTRAL_7B};
use crate::hardware::npu::{A100, GRACE_CPU, H100, SPR_CPU};
use crate::hardware::roofline::{LlmCluster, PrefillItem};
use crate::rag::ivfpq::IvfPq;
use crate::rag::RagEngine;
use crate::util::bench::Table;
use crate::workload::request::RagParams;

#[derive(Debug, Clone)]
pub struct Fig9Row {
    pub embed_model: &'static str,
    pub hw: &'static str,
    pub embed_s: f64,
    pub retrieve_s: f64,
    pub rerank_s: f64,
    pub transfer_s: f64,
    pub prefill_s: f64,
    pub ttft_s: f64,
    pub transfer_pct: f64,
}

pub fn run(_fast: bool) -> Result<Vec<Fig9Row>> {
    // paper parameters
    let params = RagParams {
        query_tokens: 128,
        docs: 20,
        doc_tokens: 512,
        centroids: 4e6,
        nprobe: 50,
        points_per_probe: 5000,
    };
    let pcie4_x4 = 32e9; // B/s — retrieval→prefill link
    let llm = LlmCluster::new(LLAMA3_8B, H100, 1);

    let mut rows = Vec::new();
    for (embed_model, spec) in [("e5-base", E5_BASE), ("mistral-7b", MISTRAL_7B)] {
        let configs = [
            ("large-cpu(grace)", spec.clone(), GRACE_CPU, GRACE_CPU),
            ("small-cpu(spr)", spec.clone(), SPR_CPU, SPR_CPU),
            ("a100+large-cpu", spec.clone(), A100, GRACE_CPU),
        ];
        for (hw, emodel, embed_npu, retr_npu) in configs {
            let engine = RagEngine::new(
                LlmCluster::new(emodel, embed_npu, 1),
                IvfPq::new(retr_npu, Default::default()),
            );
            let t = engine.batch_timing(1, &params);
            // retrieved context text moves to the prefill client over PCIe
            let ctx_tokens = params.context_tokens() as f64;
            let transfer_s = ctx_tokens * 4.0 / pcie4_x4 + 10e-6;
            // prefill of query + retrieved context on the H100
            let prefill_s = llm.prefill_time(&[PrefillItem {
                past: 0.0,
                new: params.query_tokens as f64 + ctx_tokens,
            }]);
            let ttft = t.total() + transfer_s + prefill_s;
            rows.push(Fig9Row {
                embed_model,
                hw,
                embed_s: t.embed_s,
                retrieve_s: t.retrieve_s,
                rerank_s: t.rerank_s,
                transfer_s,
                prefill_s,
                ttft_s: ttft,
                transfer_pct: transfer_s / ttft * 100.0,
            });
        }
    }
    let mut t = Table::new(&[
        "embed", "hardware", "embed(ms)", "retrieve(ms)", "rerank(ms)", "transfer(ms)",
        "prefill(ms)", "TTFT(ms)", "transfer %",
    ]);
    for r in &rows {
        t.row(&[
            r.embed_model.to_string(),
            r.hw.to_string(),
            format!("{:.1}", r.embed_s * 1e3),
            format!("{:.1}", r.retrieve_s * 1e3),
            format!("{:.2}", r.rerank_s * 1e3),
            format!("{:.3}", r.transfer_s * 1e3),
            format!("{:.1}", r.prefill_s * 1e3),
            format!("{:.1}", r.ttft_s * 1e3),
            format!("{:.2}", r.transfer_pct),
        ]);
    }
    t.print();
    println!("expected shape: mistral-7b@small-cpu dominated by embedding;");
    println!("offload to A100 collapses it; transfer <1% of TTFT everywhere.");
    Ok(rows)
}
