//! Fig 8 — goodput under reasoning workloads (§IV-A).
//!
//! Paper setup: Llama-3.1-70B on 64 GPUs (8 clients × TP8); multi-path
//! reasoning with the prefill KV shared across branches.
//!   (a) AzureConv-like inputs, outputs ~2k σ30%, 8 parallel branches
//!   (b) AzureCode-like inputs, outputs ~2k σ30%, 4 parallel branches
//! Expected shape: chunked sustains decode throughput but breaks TTFT at
//! high rates; continuous wins TTFT; disaggregated wins code overall.

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::experiments::common::{self, Scale};
use crate::util::bench::Table;
use crate::workload::trace::{Pipeline, Reasoning, TraceKind};

pub struct Fig8Result {
    pub panel: &'static str,
    pub results: Vec<common::StrategyResult>,
}

pub fn run(fast: bool) -> Result<Vec<Fig8Result>> {
    let scale = Scale::pick(
        fast,
        Scale { clients: 8, requests_per_client: 40, rates: &[0.05, 0.1, 0.2, 0.4, 0.8] },
        Scale { clients: 2, requests_per_client: 10, rates: &[0.05, 0.2] },
    );
    let slo = SloLadder::standard();
    let mut out = Vec::new();
    for (panel, in_mean, in_std, branches) in [
        ("a: Conv-like inputs, 8 branches", 1020.0, 450.0, 8usize),
        ("b: Code-like inputs, 4 branches", 1930.0, 900.0, 4usize),
    ] {
        let results = common::compare_strategies(
            "llama3-70b",
            8,
            scale.clients,
            TraceKind::Synthetic {
                in_mean,
                in_std,
                out_mean: 2000.0,
                out_std: 600.0, // 2k / σ=30%
            },
            Pipeline::Regular,
            Reasoning::MultiPath { scale: 1.0, branches },
            scale.requests_per_client,
            scale.rates,
            &slo,
        )?;
        println!("\nFig 8{panel} — goodput (requests/s meeting SLO) vs injection rate");
        let mut t = Table::new(&["strategy", "rate/client", "goodput req/s", "goodput %", "ttft_p90(ms)", "tpot_p90(ms)"]);
        for r in &results {
            for p in &r.points {
                t.row(&[
                    r.label.clone(),
                    format!("{:.2}", p.rate),
                    format!("{:.2}", p.metrics.goodput_req_s),
                    format!("{:.0}", p.metrics.goodput_frac * 100.0),
                    format!("{:.0}", p.metrics.ttft.p90 * 1e3),
                    format!("{:.1}", p.metrics.tpot.p90 * 1e3),
                ]);
            }
        }
        t.print();
        out.push(Fig8Result { panel, results });
    }
    Ok(out)
}
