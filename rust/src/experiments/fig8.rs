//! Fig 8 — goodput under reasoning workloads (§IV-A).
//!
//! Configuration lives in `scenarios/fig8.json`: Llama-3.1-70B on
//! 8 clients × TP8, multi-path reasoning with the prefill KV shared
//! across branches; panels (a) conv-like inputs / 8 branches and
//! (b) code-like inputs / 4 branches, outputs ~2k σ30%.
//!
//! Expected shape: chunked sustains decode throughput but breaks TTFT at
//! high rates; continuous wins TTFT; disaggregated wins code overall.

use anyhow::Result;

use crate::experiments::common;
use crate::scenario::Scenario;
use crate::util::bench::Table;

pub struct Fig8Result {
    pub panel: String,
    pub results: Vec<common::StrategyResult>,
}

pub fn run(fast: bool) -> Result<Vec<Fig8Result>> {
    let sc = Scenario::load("fig8")?;
    let mut out = Vec::new();
    for panel in sc.panels_or_default() {
        let results = common::compare_scenario(&sc, Some(&panel), fast)?;
        println!("\nFig 8{} — goodput (requests/s meeting SLO) vs injection rate", panel.label);
        let mut t = Table::new(&[
            "strategy", "rate/client", "goodput req/s", "goodput %", "ttft_p90(ms)", "tpot_p90(ms)",
        ]);
        for r in &results {
            for p in &r.points {
                t.row(&[
                    r.label.clone(),
                    format!("{:.2}", p.rate),
                    format!("{:.2}", p.metrics.goodput_req_s),
                    format!("{:.0}", p.metrics.goodput_frac * 100.0),
                    format!("{:.0}", p.metrics.ttft.p90 * 1e3),
                    format!("{:.1}", p.metrics.tpot.p90 * 1e3),
                ]);
            }
        }
        t.print();
        out.push(Fig8Result {
            panel: panel.label.clone(),
            results,
        });
    }
    Ok(out)
}
