//! Shared machinery for the paper-experiment regenerators: scenario
//! sweeps and normalized reporting (Figs 10–12 / Table III methodology,
//! §V-A). The actual strategy rosters, scales and workloads live in the
//! scenario files under `scenarios/` — this module only runs and prints
//! them.

use anyhow::Result;

use crate::scenario::runner;
use crate::scenario::{Panel, Scenario};

/// One strategy's sweep outcome (re-exported from the scenario runner so
/// benches keep their `experiments::common::StrategyResult` path).
pub use crate::scenario::runner::StrategySweep as StrategyResult;

/// Run the scenario's batching-strategy comparison for one panel at its
/// fast/full scale (the §V-A methodology).
pub fn compare_scenario(
    sc: &Scenario,
    panel: Option<&Panel>,
    fast: bool,
) -> Result<Vec<StrategyResult>> {
    runner::sweep(sc, panel, fast)
}

/// Print the Fig 10-style table: per strategy × rate, normalized
/// throughput and throughput/energy (baseline = continuous @ lowest rate).
pub fn print_normalized(results: &[StrategyResult], caption: &str) {
    use crate::util::bench::Table;
    let base = results
        .iter()
        .find(|r| r.label == "continuous")
        .and_then(|r| r.points.iter().find(|p| p.slo_ok))
        .map(|p| (p.metrics.throughput_tok_s, p.metrics.tok_per_joule));
    let (base_t, base_e) = base.unwrap_or((1.0, 1.0));
    println!("\n{caption}");
    println!("(normalized to continuous @ lowest SLO-passing rate)");
    let mut t = Table::new(&[
        "strategy", "rate/client", "thr(norm)", "thr/J(norm)", "ttft_p50(ms)", "tpot_p50(ms)", "SLO",
    ]);
    for r in results {
        for p in &r.points {
            t.row(&[
                r.label.clone(),
                format!("{:.2}", p.rate),
                format!("{:.2}", p.metrics.throughput_tok_s / base_t.max(1e-9)),
                format!("{:.2}", p.metrics.tok_per_joule / base_e.max(1e-9)),
                format!("{:.0}", p.metrics.ttft.p50 * 1e3),
                format!("{:.1}", p.metrics.tpot.p50 * 1e3),
                if p.slo_ok { "ok".into() } else { "x".into() },
            ]);
        }
    }
    t.print();
}

/// Winner summary across objectives (feeds Table III). Total
/// comparisons throughout — the per-strategy bests can legitimately
/// carry NaN metrics (e.g. a zero-makespan degenerate point), and a
/// NaN must lose the cross-strategy ranking instead of panicking the
/// way `partial_cmp().unwrap()` did (the same convention as
/// `driver::best_under_slo`).
pub fn winners(results: &[StrategyResult]) -> (Option<String>, Option<String>, Option<String>) {
    fn nan_loses_min(x: f64) -> f64 {
        if x.is_nan() {
            f64::INFINITY
        } else {
            x
        }
    }
    fn nan_loses_max(x: f64) -> f64 {
        if x.is_nan() {
            f64::NEG_INFINITY
        } else {
            x
        }
    }
    let ttft = results
        .iter()
        .filter_map(|r| r.best_ttft().map(|t| (r.label.clone(), t)))
        .min_by(|a, b| nan_loses_min(a.1).total_cmp(&nan_loses_min(b.1)))
        .map(|(l, _)| l);
    let thr = results
        .iter()
        .filter_map(|r| r.best().map(|p| (r.label.clone(), p.metrics.throughput_tok_s)))
        .max_by(|a, b| nan_loses_max(a.1).total_cmp(&nan_loses_max(b.1)))
        .map(|(l, _)| l);
    let energy = results
        .iter()
        .filter_map(|r| r.best_energy().map(|p| (r.label.clone(), p.metrics.tok_per_joule)))
        .max_by(|a, b| nan_loses_max(a.1).total_cmp(&nan_loses_max(b.1)))
        .map(|(l, _)| l);
    (ttft, thr, energy)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn compare_scenario_sweeps_the_roster() {
        let sc = Scenario::from_json(
            "mini",
            Json::parse(
                r#"{
                "model": "llama3-70b", "npu": "h100", "tp": 8,
                "batching": ["continuous", "chunked:512", "mixed",
                             "disagg:0.625", "disagg:0.375"],
                "perf_model": "roofline",
                "workload": { "trace": "azure-conv" },
                "sweep": { "clients": 2, "requests_per_client": 5, "rates": [1.0] }
            }"#,
            )
            .unwrap(),
        )
        .unwrap();
        let results = compare_scenario(&sc, None, true).unwrap();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.points.len(), 1);
            assert!(r.points[0].metrics.n_serviced > 0, "{}", r.label);
        }
        // the paper's 62.5%/37.5% splits resolve against the pool size
        assert_eq!(results[3].label, "disagg-1P/1D");
        let (_, thr, _) = winners(&results);
        let _ = thr; // may be None if nothing passes SLO at this scale
    }

    #[test]
    fn winners_tolerate_nan_metrics_without_panicking_or_crowning_them() {
        use crate::metrics::RunMetrics;
        use crate::sim::driver::SweepPoint;
        use crate::util::stats::Summary;

        let point = |thr: f64, tpj: f64, ttft_p50: f64| SweepPoint {
            rate: 1.0,
            metrics: RunMetrics {
                throughput_tok_s: thr,
                tok_per_joule: tpj,
                ttft: Summary {
                    p50: ttft_p50,
                    ..Default::default()
                },
                ..Default::default()
            },
            slo_ok: true,
        };
        // a strategy whose only SLO-passing point has NaN metrics (a
        // zero-makespan degenerate) ranked against a healthy one: the
        // pre-fix partial_cmp().unwrap() panicked here
        let results = vec![
            StrategyResult {
                label: "nan".into(),
                points: vec![point(f64::NAN, f64::NAN, f64::NAN)],
            },
            StrategyResult {
                label: "healthy".into(),
                points: vec![point(100.0, 5.0, 0.2)],
            },
        ];
        let (ttft, thr, energy) = winners(&results);
        assert_eq!(ttft.as_deref(), Some("healthy"), "NaN TTFT must lose");
        assert_eq!(thr.as_deref(), Some("healthy"), "NaN throughput must lose");
        assert_eq!(energy.as_deref(), Some("healthy"), "NaN tok/J must lose");
        // all-NaN input: no panic, some winner is reported
        let all_nan = vec![StrategyResult {
            label: "only".into(),
            points: vec![point(f64::NAN, f64::NAN, f64::NAN)],
        }];
        let (t, h, e) = winners(&all_nan);
        assert!(t.is_some() && h.is_some() && e.is_some());
    }
}
