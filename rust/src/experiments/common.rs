//! Shared machinery for the paper-experiment regenerators: the batching
//! strategy roster, rate sweeps with SLO filtering, and normalized
//! reporting (Figs 10–12 / Table III methodology, §V-A).

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::hardware::npu::H100;
use crate::metrics::RunMetrics;
use crate::scheduler::BatchingKind;
use crate::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use crate::sim::driver::{self, SweepPoint};
use crate::workload::trace::{Pipeline, Reasoning, TraceKind, WorkloadSpec};

/// The Fig 10 strategy roster for a pool of `n` clients: continuous,
/// chunked, mixed, and the two disaggregated splits the paper sweeps
/// (prefill-heavy ~62% and decode-heavy ~37%).
pub fn strategy_roster(n: usize) -> Vec<PoolSpec> {
    let hi = ((n as f64 * 0.625).round() as usize).clamp(1, n - 1);
    let lo = ((n as f64 * 0.375).round() as usize).clamp(1, n - 1);
    vec![
        PoolSpec::Combined { kind: BatchingKind::Continuous, n },
        PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 512 }, n },
        PoolSpec::Combined { kind: BatchingKind::Mixed, n },
        PoolSpec::Disaggregated { prefill: hi, decode: n - hi, local: false },
        PoolSpec::Disaggregated { prefill: lo, decode: n - lo, local: false },
    ]
}

/// One strategy's sweep outcome.
#[derive(Debug, Clone)]
pub struct StrategyResult {
    pub label: String,
    pub points: Vec<SweepPoint>,
}

impl StrategyResult {
    /// Best SLO-satisfying throughput (tokens/s); None if nothing passes.
    pub fn best(&self) -> Option<&SweepPoint> {
        driver::best_under_slo(&self.points)
    }

    /// Best point by throughput/energy under SLO.
    pub fn best_energy(&self) -> Option<&SweepPoint> {
        self.points
            .iter()
            .filter(|p| p.slo_ok)
            .max_by(|a, b| {
                a.metrics
                    .tok_per_joule
                    .partial_cmp(&b.metrics.tok_per_joule)
                    .unwrap()
            })
    }

    /// Lowest p50 TTFT across swept points (TTFT objective column).
    pub fn best_ttft(&self) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.slo_ok)
            .map(|p| p.metrics.ttft.p50)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

/// Experiment scale knobs (full = paper scale, fast = CI scale).
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub clients: usize,
    pub requests_per_client: usize,
    pub rates: &'static [f64],
}

impl Scale {
    pub fn pick(fast: bool, full: Scale, quick: Scale) -> Scale {
        let force_full = std::env::var("HERMES_FULL").is_ok();
        if fast && !force_full {
            quick
        } else {
            full
        }
    }
}

/// Run the strategy comparison for one (trace, pipeline) combination on
/// `clients`×H100(TP`tp`) serving `model` (the §V-A methodology).
pub fn compare_strategies(
    model: &'static str,
    tp: usize,
    clients: usize,
    trace: TraceKind,
    pipeline: Pipeline,
    reasoning: Reasoning,
    requests_per_client: usize,
    rates: &[f64],
    slo: &SloLadder,
) -> Result<Vec<StrategyResult>> {
    let mut out = Vec::new();
    for pool in strategy_roster(clients) {
        let mut spec = ServingSpec::new(model, H100, tp, pool).with_perf(PerfBackend::Poly);
        // pipelines needing auxiliary clients
        match pipeline {
            Pipeline::Rag(_) => {
                spec = spec.with_rag(crate::sim::builder::RagSpec {
                    count: (clients / 8).max(1),
                    embed_model: crate::hardware::models::E5_BASE,
                    embed_npu: crate::hardware::npu::A100,
                    retrieval_npu: crate::hardware::npu::GRACE_CPU,
                    ivf: Default::default(),
                    max_batch: 0,
                });
            }
            Pipeline::KvRetrieval(_) => {
                spec = spec.with_kv_retrieval(crate::sim::builder::KvRetrievalSpec {
                    count: (clients / 8).max(1),
                    storage: crate::memory::storage::StorageConfig::PlatformShared,
                    scenario: crate::memory::storage::KvScenario::Private,
                    max_batch: 0,
                    ports: 4,
                });
            }
            _ => {}
        }
        let workload = WorkloadSpec {
            model,
            trace,
            pipeline,
            reasoning,
            arrival: crate::util::rng::Arrival::Poisson { rate: 1.0 }, // overridden by sweep
            n_requests: requests_per_client * clients,
            seed: 42,
        };
        let points = driver::sweep_rates(&spec, &workload, slo, rates)?;
        out.push(StrategyResult {
            label: spec.pool.label(),
            points,
        });
    }
    Ok(out)
}

/// Print the Fig 10-style table: per strategy × rate, normalized
/// throughput and throughput/energy (baseline = continuous @ lowest rate).
pub fn print_normalized(results: &[StrategyResult], caption: &str) {
    use crate::util::bench::Table;
    let base = results
        .iter()
        .find(|r| r.label == "continuous")
        .and_then(|r| r.points.iter().find(|p| p.slo_ok))
        .map(|p| (p.metrics.throughput_tok_s, p.metrics.tok_per_joule));
    let (base_t, base_e) = base.unwrap_or((1.0, 1.0));
    println!("\n{caption}");
    println!("(normalized to continuous @ lowest SLO-passing rate)");
    let mut t = Table::new(&[
        "strategy", "rate/client", "thr(norm)", "thr/J(norm)", "ttft_p50(ms)", "tpot_p50(ms)", "SLO",
    ]);
    for r in results {
        for p in &r.points {
            t.row(&[
                r.label.clone(),
                format!("{:.2}", p.rate),
                format!("{:.2}", p.metrics.throughput_tok_s / base_t.max(1e-9)),
                format!("{:.2}", p.metrics.tok_per_joule / base_e.max(1e-9)),
                format!("{:.0}", p.metrics.ttft.p50 * 1e3),
                format!("{:.1}", p.metrics.tpot.p50 * 1e3),
                if p.slo_ok { "ok".into() } else { "x".into() },
            ]);
        }
    }
    t.print();
}

/// Winner summary across objectives (feeds Table III).
pub fn winners(results: &[StrategyResult]) -> (Option<String>, Option<String>, Option<String>) {
    let ttft = results
        .iter()
        .filter_map(|r| r.best_ttft().map(|t| (r.label.clone(), t)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(l, _)| l);
    let thr = results
        .iter()
        .filter_map(|r| r.best().map(|p| (r.label.clone(), p.metrics.throughput_tok_s)))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(l, _)| l);
    let energy = results
        .iter()
        .filter_map(|r| r.best_energy().map(|p| (r.label.clone(), p.metrics.tok_per_joule)))
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(l, _)| l);
    (ttft, thr, energy)
}

/// Aggregate run stats line (shared by several experiments).
pub fn summarize(label: &str, m: &RunMetrics) {
    println!(
        "{label:<28} e2e_p50={:.2}s p90={:.2}s p99={:.2}s  thr={:.0} tok/s  goodput={:.0}%",
        m.e2e.p50,
        m.e2e.p90,
        m.e2e.p99,
        m.throughput_tok_s,
        m.goodput_frac * 100.0
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_covers_five_strategies() {
        let r = strategy_roster(32);
        assert_eq!(r.len(), 5);
        assert!(matches!(r[0], PoolSpec::Combined { kind: BatchingKind::Continuous, n: 32 }));
        // 62.5% of 32 = 20P/12D — the paper's split
        assert_eq!(r[3], PoolSpec::Disaggregated { prefill: 20, decode: 12, local: false });
        assert_eq!(r[4], PoolSpec::Disaggregated { prefill: 12, decode: 20, local: false });
    }

    #[test]
    fn roster_degenerates_gracefully() {
        for pool in strategy_roster(2) {
            assert!(pool.n_clients() == 2);
        }
    }

    #[test]
    fn scale_pick_honours_fast() {
        let full = Scale { clients: 32, requests_per_client: 60, rates: &[1.0] };
        let quick = Scale { clients: 4, requests_per_client: 10, rates: &[1.0] };
        assert_eq!(Scale::pick(true, full, quick).clients, 4);
        assert_eq!(Scale::pick(false, full, quick).clients, 32);
    }

    #[test]
    fn small_compare_produces_results() {
        let slo = SloLadder::standard();
        let results = compare_strategies(
            "llama3-70b",
            8,
            2,
            TraceKind::AzureConv,
            Pipeline::Regular,
            Reasoning::None,
            5,
            &[1.0],
            &slo,
        )
        .unwrap();
        assert_eq!(results.len(), 5);
        for r in &results {
            assert_eq!(r.points.len(), 1);
            assert!(r.points[0].metrics.n_serviced > 0, "{}", r.label);
        }
    }
}
