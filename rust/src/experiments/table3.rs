//! Table III — batching-strategy recommendation matrix.
//!
//! For each (trace, request type, system size), sweep the strategy
//! roster and report the winner per optimization objective (TTFT,
//! throughput, throughput/energy). The (trace × request-type) grid is
//! the panel list of `scenarios/table3_small.json` /
//! `scenarios/table3_large.json`; small = 4×TP2, large = 32×TP2,
//! serving Llama-3-70B (§V-A, Table III caption).

use anyhow::{Context, Result};

use crate::experiments::common;
use crate::scenario::Scenario;
use crate::util::bench::Table;

#[derive(Debug, Clone)]
pub struct Table3Row {
    pub trace: String,
    pub request_type: String,
    pub system: &'static str,
    pub ttft: String,
    pub throughput: String,
    pub throughput_energy: String,
}

pub fn run(fast: bool) -> Result<Vec<Table3Row>> {
    let small = Scenario::load("table3_small")?;
    let large = Scenario::load("table3_large")?;

    let mut rows = Vec::new();
    // both scenarios share the panel grid; iterate small's list so row
    // order matches the paper's table
    for panel in small.panels_or_default() {
        for (system, sc) in [("small", &small), ("large", &large)] {
            // the two files must carry the same panel grid — a silent
            // substitution would compute the 'large' column from the
            // small file's panel definition
            let sc_panel = sc
                .panels_or_default()
                .into_iter()
                .find(|p| p.label == panel.label)
                .with_context(|| {
                    format!("panel '{}' missing from scenario '{}'", panel.label, sc.name)
                })?;
            let results = common::compare_scenario(sc, Some(&sc_panel), fast)?;
            let (ttft, thr, energy) = common::winners(&results);
            rows.push(Table3Row {
                trace: panel.raw.str_or("trace", "?").to_string(),
                request_type: panel.raw.str_or("request_type", "?").to_string(),
                system,
                ttft: ttft.unwrap_or_else(|| "-".into()),
                throughput: thr.unwrap_or_else(|| "-".into()),
                throughput_energy: energy.unwrap_or_else(|| "-".into()),
            });
        }
    }

    let mut t = Table::new(&["trace", "request type", "system", "TTFT", "throughput", "throughput/energy"]);
    for r in &rows {
        t.row(&[
            r.trace.clone(),
            r.request_type.clone(),
            r.system.to_string(),
            r.ttft.clone(),
            r.throughput.clone(),
            r.throughput_energy.clone(),
        ]);
    }
    t.print();
    println!("paper's headline: disaggregated wins throughput/energy almost");
    println!("everywhere; continuous wins TTFT; chunked wins raw throughput at high rates.");
    Ok(rows)
}
