//! Table III — batching-strategy recommendation matrix.
//!
//! For each (trace, request type, system size), sweep the strategy
//! roster and report the winner per optimization objective (TTFT,
//! throughput, throughput/energy). Small = 4×TP2, Large = 32×TP2,
//! serving Llama-3-70B (§V-A, Table III caption).

use anyhow::Result;

use crate::config::slo::SloLadder;
use crate::experiments::common::{self, Scale};
use crate::util::bench::Table;
use crate::workload::request::{KvParams, RagParams};
use crate::workload::trace::{Pipeline, Reasoning, TraceKind};

#[derive(Debug, Clone)]
pub struct Table3Row {
    pub trace: &'static str,
    pub request_type: &'static str,
    pub system: &'static str,
    pub ttft: String,
    pub throughput: String,
    pub throughput_energy: String,
}

pub fn run(fast: bool) -> Result<Vec<Table3Row>> {
    let small = Scale::pick(
        fast,
        Scale { clients: 4, requests_per_client: 30, rates: &[0.5, 1.0, 2.0, 4.0] },
        Scale { clients: 2, requests_per_client: 8, rates: &[0.5, 2.0] },
    );
    let large = Scale::pick(
        fast,
        Scale { clients: 32, requests_per_client: 30, rates: &[0.5, 1.0, 2.0, 4.0] },
        Scale { clients: 4, requests_per_client: 8, rates: &[0.5, 2.0] },
    );

    let request_types: Vec<(&'static str, Pipeline, Reasoning, SloLadder)> = vec![
        ("regular", Pipeline::Regular, Reasoning::None, SloLadder::standard()),
        (
            "rag",
            Pipeline::Rag(RagParams { docs: 6, doc_tokens: 500, ..Default::default() }),
            Reasoning::None,
            SloLadder::retrieval(),
        ),
        (
            "memory-cache",
            Pipeline::KvRetrieval(KvParams { cached_tokens: 3000 }),
            Reasoning::None,
            SloLadder::retrieval(),
        ),
        (
            "reasoning",
            Pipeline::Regular,
            Reasoning::MultiPath { scale: 4.0, branches: 8 },
            SloLadder::standard(),
        ),
    ];

    let mut rows = Vec::new();
    for (trace_name, trace) in [("code", TraceKind::AzureCode), ("conv", TraceKind::AzureConv)] {
        for (req_name, pipeline, reasoning, slo) in &request_types {
            // the paper only evaluates reasoning on conversational traces
            if *req_name == "reasoning" && trace_name == "code" {
                continue;
            }
            for (sys_name, scale) in [("small", small), ("large", large)] {
                let results = common::compare_strategies(
                    "llama3-70b",
                    2,
                    scale.clients,
                    trace,
                    *pipeline,
                    *reasoning,
                    scale.requests_per_client,
                    scale.rates,
                    slo,
                )?;
                let (ttft, thr, energy) = common::winners(&results);
                rows.push(Table3Row {
                    trace: trace_name,
                    request_type: req_name,
                    system: sys_name,
                    ttft: ttft.unwrap_or_else(|| "-".into()),
                    throughput: thr.unwrap_or_else(|| "-".into()),
                    throughput_energy: energy.unwrap_or_else(|| "-".into()),
                });
            }
        }
    }

    let mut t = Table::new(&["trace", "request type", "system", "TTFT", "throughput", "throughput/energy"]);
    for r in &rows {
        t.row(&[
            r.trace.to_string(),
            r.request_type.to_string(),
            r.system.to_string(),
            r.ttft.clone(),
            r.throughput.clone(),
            r.throughput_energy.clone(),
        ]);
    }
    t.print();
    println!("paper's headline: disaggregated wins throughput/energy almost");
    println!("everywhere; continuous wins TTFT; chunked wins raw throughput at high rates.");
    Ok(rows)
}
