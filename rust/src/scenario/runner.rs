//! Generic scenario execution: resolve a [`Scenario`](super::Scenario)'s
//! roster at the requested scale and rate-sweep every strategy (the
//! §V-A "gradually increase the per-client request rate" methodology).
//! This is what `hermes scenario <name>` and all `experiments::fig*`
//! wrappers run; no Rust code is needed to execute a new scenario file.

use anyhow::Result;

use super::{Panel, Scenario};
use crate::sim::driver::{self, SweepPoint};
use crate::sim::parallel;

/// One strategy's sweep outcome.
#[derive(Debug, Clone)]
pub struct StrategySweep {
    /// the resolved pool label (e.g. `continuous`, `disagg-5P/3D`)
    pub label: String,
    pub points: Vec<SweepPoint>,
}

impl StrategySweep {
    /// Best SLO-satisfying throughput (tokens/s); None if nothing passes.
    pub fn best(&self) -> Option<&SweepPoint> {
        driver::best_under_slo(&self.points)
    }

    /// Best point by throughput/energy under SLO. Total comparison, like
    /// [`driver::best_under_slo`]: a NaN metric loses instead of
    /// panicking.
    pub fn best_energy(&self) -> Option<&SweepPoint> {
        fn key(x: f64) -> f64 {
            if x.is_nan() {
                f64::NEG_INFINITY
            } else {
                x
            }
        }
        self.points
            .iter()
            .filter(|p| p.slo_ok)
            .max_by(|a, b| key(a.metrics.tok_per_joule).total_cmp(&key(b.metrics.tok_per_joule)))
    }

    /// Lowest p50 TTFT across swept points (TTFT objective column).
    /// Total comparison: a NaN sample loses instead of panicking.
    pub fn best_ttft(&self) -> Option<f64> {
        self.points
            .iter()
            .filter(|p| p.slo_ok)
            .map(|p| p.metrics.ttft.p50)
            .min_by(|a, b| {
                let k = |x: f64| if x.is_nan() { f64::INFINITY } else { x };
                k(*a).total_cmp(&k(*b))
            })
    }
}

/// Sweep every roster entry at an explicit scale (pool size, request
/// count per client, per-client rates).
///
/// The roster × rates grid is flattened into one submission-ordered
/// unit list and dispatched on the configured worker pool
/// ([`parallel::jobs`], default 1 = inline serial), so a `--jobs N`
/// run drains *strategies* concurrently, not just the rates within
/// one strategy. Every unit is an independent simulation; results are
/// regrouped by roster order, so the output is identical to the serial
/// per-strategy loop.
pub fn sweep_at(
    sc: &Scenario,
    panel: Option<&Panel>,
    clients: usize,
    requests_per_client: usize,
    rates: &[f64],
) -> Result<Vec<StrategySweep>> {
    // the workload and SLO ladder are identical across strategies by
    // construction — build them once, outside the fan-out
    let mix = sc.workload(panel, requests_per_client * clients)?;
    let slo = sc.slo(panel, &mix)?;
    // resolve every strategy's spec up front (cheap plain data; any
    // model-catalog interning this triggers happens serially here)
    let specs = sc
        .roster
        .iter()
        .map(|entry| sc.serving_panel(entry, clients, panel))
        .collect::<Result<Vec<_>>>()?;
    let n_rates = rates.len();
    let points = parallel::run(parallel::jobs(), specs.len() * n_rates, |u| {
        driver::sweep_point_mix(&specs[u / n_rates], &mix, &slo, rates[u % n_rates])
    });
    let mut it = points.into_iter();
    let mut out = Vec::with_capacity(specs.len());
    for spec in &specs {
        let points = it
            .by_ref()
            .take(n_rates)
            .collect::<Result<Vec<SweepPoint>>>()?;
        out.push(StrategySweep {
            label: spec.pool.label(),
            points,
        });
    }
    Ok(out)
}

/// Sweep every roster entry at the scenario's own fast/full scale.
pub fn sweep(sc: &Scenario, panel: Option<&Panel>, fast: bool) -> Result<Vec<StrategySweep>> {
    let scale = sc.scale(fast);
    sweep_at(sc, panel, scale.clients, scale.requests_per_client, &scale.rates)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn sweeps_roster_and_changing_batching_changes_results() {
        let sc = Scenario::from_json(
            "t",
            Json::parse(
                r#"{
                "model": "llama3-70b", "npu": "h100", "tp": 8,
                "batching": ["static", "continuous", "chunked:512"],
                "perf_model": "roofline",
                "workload": { "trace": "azure-conv" },
                "sweep": { "clients": 1, "requests_per_client": 25, "rates": [2.0] }
            }"#,
            )
            .unwrap(),
        )
        .unwrap();
        let sweeps = sweep(&sc, None, true).unwrap();
        assert_eq!(sweeps.len(), 3);
        assert_eq!(sweeps[0].label, "static");
        assert_eq!(sweeps[1].label, "continuous");
        assert_eq!(sweeps[2].label, "chunked");
        for s in &sweeps {
            assert_eq!(s.points.len(), 1);
            assert!(s.points[0].metrics.n_serviced > 0, "{}", s.label);
        }
        // the acceptance check of the scenario refactor: identical data,
        // different `batching` entry → different reported latency under
        // the same arrival stream, with no recompilation
        let ttft = |s: &StrategySweep| s.points[0].metrics.ttft.p50;
        assert!(
            (ttft(&sweeps[0]) - ttft(&sweeps[1])).abs() > 1e-9
                || (ttft(&sweeps[2]) - ttft(&sweeps[1])).abs() > 1e-9,
            "batching policy had no effect on TTFT: static={} continuous={} chunked={}",
            ttft(&sweeps[0]),
            ttft(&sweeps[1]),
            ttft(&sweeps[2])
        );
    }
}
