//! Declarative scenario registry: JSON files under `scenarios/` →
//! complete, sweepable serving experiments (see `docs/scenarios.md`).
//!
//! A scenario bundles everything the paper varies between figures —
//! hardware pool, workload mix (regular / RAG / KV-retrieval /
//! reasoning fractions), batching-policy roster, SLO ladder, rate sweep
//! and fast/full scale knobs — so a new experiment is a data file, not
//! Rust code. Every `experiments::fig*` regenerator is a thin wrapper
//! over one of these files, and `hermes scenario <name>` runs any of
//! them (or any path) from the CLI.
//!
//! The schema is the config-system schema ([`crate::config`]) plus four
//! scenario-only keys:
//!
//! * `"batching"` — the policy roster: an array of entries, each either
//!   a kind string (`"continuous"`, `"chunked:512"`, `"static"`,
//!   `"mixed"`), a fractional disaggregated split resolved against the
//!   swept pool size (`"disagg:0.625"`, `"disagg-local:0.5"`), an
//!   absolute split (`"disagg:20P/12D"`), or a full `pool` object.
//! * `"workload"` — one class object, or an array of classes each
//!   carrying a `"fraction"` (the workload mix).
//! * `"sweep"` — `{"full": {...}, "fast": {...}}` scale knobs:
//!   `clients`, `requests_per_client`, `rates`.
//! * `"panels"` — optional list of `{label, workload: {patch}, slo?}`
//!   sub-experiments sharing the roster (a paper figure's (a)/(b) panels).
//!
//! Figure-specific one-off knobs live under `"extras"` and are read by
//! the figure wrapper through [`Scenario::extras`].

pub mod runner;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::slo::SloLadder;
use crate::config::{self, parse_batching_kind};
use crate::model::ModelId;
use crate::scheduler::BatchingKind;
use crate::sim::builder::{PoolSpec, ServingSpec};
use crate::util::json::Json;
use crate::workload::trace::{WorkloadMix, WorkloadSpec};

/// One batching-roster entry, resolved against the swept pool size.
#[derive(Debug, Clone, PartialEq)]
pub enum RosterEntry {
    /// n identical clients of one kind
    Kind(BatchingKind),
    /// disaggregated split as a prefill fraction of the pool
    DisaggFrac { prefill_frac: f64, local: bool },
    /// a fully specified pool (ignores the swept size)
    Fixed(PoolSpec),
}

impl RosterEntry {
    /// Parse the string grammar (see module docs).
    pub fn parse(s: &str) -> Result<RosterEntry> {
        let disagg = |rest: &str, local: bool| -> Result<RosterEntry> {
            if let Some((p, d)) = rest.split_once('/') {
                let prefill: usize = p
                    .trim_end_matches(['P', 'p'])
                    .parse()
                    .with_context(|| format!("bad prefill count in 'disagg:{rest}'"))?;
                let decode: usize = d
                    .trim_end_matches(['D', 'd'])
                    .parse()
                    .with_context(|| format!("bad decode count in 'disagg:{rest}'"))?;
                return Ok(RosterEntry::Fixed(PoolSpec::Disaggregated {
                    prefill,
                    decode,
                    local,
                }));
            }
            let frac: f64 = rest
                .parse()
                .with_context(|| format!("bad prefill fraction in 'disagg:{rest}'"))?;
            if !(0.0..1.0).contains(&frac) || frac == 0.0 {
                bail!("disaggregated prefill fraction must be in (0, 1), got {frac}");
            }
            Ok(RosterEntry::DisaggFrac {
                prefill_frac: frac,
                local,
            })
        };
        if let Some(rest) = s.strip_prefix("disagg-local:") {
            disagg(rest, true)
        } else if let Some(rest) = s.strip_prefix("disagg:") {
            disagg(rest, false)
        } else {
            Ok(RosterEntry::Kind(parse_batching_kind(s)?))
        }
    }

    /// Resolve to a concrete pool of `n` LLM clients.
    pub fn pool(&self, n: usize) -> PoolSpec {
        match self {
            RosterEntry::Kind(kind) => PoolSpec::Combined { kind: *kind, n },
            RosterEntry::DisaggFrac { prefill_frac, local } => {
                if n < 2 {
                    // a split needs both roles
                    PoolSpec::Disaggregated { prefill: 1, decode: 1, local: *local }
                } else {
                    let prefill =
                        (((n as f64) * prefill_frac).round() as usize).clamp(1, n - 1);
                    PoolSpec::Disaggregated {
                        prefill,
                        decode: n - prefill,
                        local: *local,
                    }
                }
            }
            RosterEntry::Fixed(pool) => pool.clone(),
        }
    }
}

/// One sub-experiment of a scenario (e.g. a paper figure's (a)/(b)
/// panels): a label, a shallow patch merged over every workload class,
/// and an optional SLO-ladder override.
#[derive(Debug, Clone)]
pub struct Panel {
    pub label: String,
    /// shallow JSON patch applied to each workload class object
    pub patch: Json,
    /// `"standard"` / `"retrieval"` / `"auto"` override
    pub slo: Option<String>,
    /// the raw panel object, for wrapper-specific keys (e.g. Table III's
    /// `trace`/`request_type` columns)
    pub raw: Json,
}

impl Panel {
    fn from_json(j: &Json) -> Result<Panel> {
        Ok(Panel {
            label: j
                .get("label")
                .and_then(Json::as_str)
                .context("panel needs a 'label'")?
                .to_string(),
            patch: j.get("workload").cloned().unwrap_or_else(Json::obj),
            slo: j.get("slo").and_then(Json::as_str).map(str::to_string),
            raw: j.clone(),
        })
    }
}

/// Fast/full scale knobs for one scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioScale {
    /// LLM clients in the pool (roster entries resolve against this)
    pub clients: usize,
    pub requests_per_client: usize,
    /// per-client injection rates to sweep
    pub rates: Vec<f64>,
}

impl ScenarioScale {
    fn from_json(j: &Json, default: &ScenarioScale) -> Result<ScenarioScale> {
        let rates = match j.get("rates") {
            None => default.rates.clone(),
            Some(r) => {
                // strict: a present-but-malformed rate ladder must error,
                // not silently sweep nothing
                let arr = r.as_arr().context("'rates' must be an array")?;
                let rates: Vec<f64> = arr
                    .iter()
                    .enumerate()
                    .map(|(i, v)| {
                        v.as_f64()
                            .with_context(|| format!("'rates[{i}]' is not a number"))
                    })
                    .collect::<Result<_>>()?;
                if rates.is_empty() {
                    bail!("'rates' must not be empty");
                }
                rates
            }
        };
        Ok(ScenarioScale {
            clients: j.usize_or("clients", default.clients),
            requests_per_client: j.usize_or("requests_per_client", default.requests_per_client),
            rates,
        })
    }
}

/// A parsed scenario file. See the module docs for the schema.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub title: String,
    /// paper figure/table this reproduces, if any
    pub figure: Option<String>,
    /// the full parsed document (serving keys, workload, extras…)
    pub doc: Json,
    pub roster: Vec<RosterEntry>,
    pub panels: Vec<Panel>,
    /// models THIS file's `model_catalog` declares (the registry is
    /// process-global and append-only, so [`Scenario::check`] uses this
    /// to reject references that only resolve because some *other*
    /// scenario registered the name earlier in the same process)
    pub catalog_models: Vec<ModelId>,
    full: ScenarioScale,
    fast: ScenarioScale,
}

impl Scenario {
    // ---- registry ---------------------------------------------------------

    /// Scenario directory: `$HERMES_SCENARIOS`, else `./scenarios` when
    /// present, else `<crate root>/scenarios` (so tests and benches find
    /// the shipped files regardless of the working directory).
    pub fn dir() -> PathBuf {
        if let Ok(d) = std::env::var("HERMES_SCENARIOS") {
            return PathBuf::from(d);
        }
        let cwd = PathBuf::from("scenarios");
        if cwd.is_dir() {
            return cwd;
        }
        Path::new(env!("CARGO_MANIFEST_DIR")).join("scenarios")
    }

    /// Names of every scenario shipped in [`Scenario::dir`], sorted.
    pub fn list() -> Vec<String> {
        let mut names: Vec<String> = std::fs::read_dir(Scenario::dir())
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| {
                        let p = e.path();
                        if p.extension().is_some_and(|x| x == "json") {
                            p.file_stem().map(|s| s.to_string_lossy().into_owned())
                        } else {
                            None
                        }
                    })
                    .collect()
            })
            .unwrap_or_default();
        names.sort();
        names
    }

    /// Load by registry name (`"fig10"`) or by path (`"my/exp.json"`).
    pub fn load(name_or_path: &str) -> Result<Scenario> {
        let as_path = Path::new(name_or_path);
        if name_or_path.ends_with(".json") || as_path.is_file() {
            Scenario::from_file(as_path)
        } else {
            let path = Scenario::dir().join(format!("{name_or_path}.json"));
            Scenario::from_file(&path).with_context(|| {
                format!(
                    "scenario '{name_or_path}' not found (known: {})",
                    Scenario::list().join(", ")
                )
            })
        }
    }

    pub fn from_file(path: &Path) -> Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading scenario {}", path.display()))?;
        let doc = Json::parse(&text)
            .with_context(|| format!("parsing scenario {}", path.display()))?;
        let stem = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| "scenario".to_string());
        Scenario::from_json(&stem, doc)
    }

    // ---- parsing ----------------------------------------------------------

    pub fn from_json(default_name: &str, doc: Json) -> Result<Scenario> {
        let name = doc.str_or("name", default_name).to_string();
        let title = doc.str_or("title", &name).to_string();
        let figure = doc.get("figure").and_then(Json::as_str).map(str::to_string);

        // register catalog models up front: `workload()` can be called
        // before `serving()` (the runner does), and both may reference
        // catalog-only names
        let mut catalog_models = Vec::new();
        if let Some(cat) = doc.get("model_catalog") {
            config::parse_model_catalog(cat)
                .with_context(|| format!("scenario '{name}': model_catalog"))?;
            for entry in cat.as_arr().unwrap_or(&[]) {
                if let Some(n) = entry.get("name").and_then(Json::as_str) {
                    // just registered above, so resolution cannot fail
                    catalog_models.push(ModelId::named(n));
                }
            }
        }

        // roster: "batching" entries, else the config-style "pool" object
        let roster: Vec<RosterEntry> = match doc.get("batching") {
            Some(Json::Arr(entries)) => entries
                .iter()
                .map(|e| match e {
                    Json::Str(s) => RosterEntry::parse(s),
                    Json::Obj(_) => Ok(RosterEntry::Fixed(config::parse_pool(e)?)),
                    _ => bail!("roster entries must be strings or pool objects"),
                })
                .collect::<Result<_>>()?,
            Some(Json::Str(s)) => vec![RosterEntry::parse(s)?],
            Some(_) => bail!("'batching' must be a string or an array"),
            None => {
                let pool = doc
                    .get("pool")
                    .context("scenario needs 'batching' (roster) or 'pool'")?;
                vec![RosterEntry::Fixed(config::parse_pool(pool)?)]
            }
        };

        let panels = match doc.get("panels") {
            Some(Json::Arr(ps)) => ps
                .iter()
                .map(Panel::from_json)
                .collect::<Result<Vec<Panel>>>()?,
            Some(_) => bail!("'panels' must be an array"),
            None => Vec::new(),
        };

        let default_scale = ScenarioScale {
            clients: 4,
            requests_per_client: 20,
            rates: vec![0.5, 1.0, 2.0, 4.0],
        };
        let sweep = doc.get("sweep").cloned().unwrap_or_else(Json::obj);
        let full = match sweep.get("full") {
            Some(j) => ScenarioScale::from_json(j, &default_scale),
            None => ScenarioScale::from_json(&sweep, &default_scale),
        }
        .context("parsing sweep.full")?;
        let fast = match sweep.get("fast") {
            Some(j) => ScenarioScale::from_json(j, &full).context("parsing sweep.fast")?,
            None => full.clone(),
        };

        let sc = Scenario {
            name,
            title,
            figure,
            doc,
            roster,
            panels,
            catalog_models,
            full,
            fast,
        };
        // fail fast on malformed serving/workload sections
        sc.serving(&sc.roster[0], sc.full.clients)?;
        sc.workload(sc.panels.first(), 8)?;
        Ok(sc)
    }

    // ---- resolution -------------------------------------------------------

    /// Does a run requested with `fast` actually use the fast scale?
    /// (`HERMES_FULL=1` forces paper scale.) Figure wrappers use this to
    /// pick between `*_fast`/`*_full` keys in `extras`.
    pub fn use_fast(&self, fast: bool) -> bool {
        fast && std::env::var("HERMES_FULL").is_err()
    }

    /// Scale knobs for this run; `HERMES_FULL=1` forces paper scale.
    pub fn scale(&self, fast: bool) -> &ScenarioScale {
        if self.use_fast(fast) {
            &self.fast
        } else {
            &self.full
        }
    }

    /// Build the serving spec for one roster entry at a pool size.
    /// Auxiliary RAG/KV/pre-post tiers scale with `clients` through their
    /// `per_llm` knobs.
    pub fn serving(&self, entry: &RosterEntry, clients: usize) -> Result<ServingSpec> {
        self.serving_panel(entry, clients, None)
    }

    /// Like [`Scenario::serving`], with a panel's serving-side overrides
    /// applied: a panel may set or replace `rag_clients`, `kv_clients`,
    /// `prepost_clients`, `network`, `granularity`, `migration`,
    /// `transfer_weight` or `faults`, and `null` removes the key — so
    /// auxiliary
    /// tiers are provisioned only for the panels whose pipeline uses
    /// them (energy accounting stays faithful to the paper's
    /// per-request-type methodology), and a disaggregation family can
    /// vary its KV hand-off pricing per panel.
    pub fn serving_panel(
        &self,
        entry: &RosterEntry,
        clients: usize,
        panel: Option<&Panel>,
    ) -> Result<ServingSpec> {
        const OVERRIDABLE: [&str; 8] = [
            "rag_clients",
            "kv_clients",
            "prepost_clients",
            "network",
            "granularity",
            "migration",
            "transfer_weight",
            "faults",
        ];
        let overrides: Vec<(&str, &Json)> = panel
            .map(|p| {
                OVERRIDABLE
                    .iter()
                    .filter_map(|k| p.raw.get(k).map(|v| (*k, v)))
                    .collect()
            })
            .unwrap_or_default();
        if overrides.is_empty() {
            return config::parse_serving(&self.doc, entry.pool(clients));
        }
        let mut doc = self.doc.clone();
        for (key, value) in overrides {
            if matches!(value, Json::Null) {
                doc.remove(key);
            } else {
                doc.set(key, value.clone());
            }
        }
        config::parse_serving(&doc, entry.pool(clients))
    }

    /// Build the workload mix for `n_requests` total, with an optional
    /// panel patch applied to every class.
    pub fn workload(&self, panel: Option<&Panel>, n_requests: usize) -> Result<WorkloadMix> {
        // primary model: 'model', else the first 'models' entry (the
        // same precedence the serving side applies)
        let model = match self.doc.get("model").and_then(Json::as_str) {
            Some(n) => ModelId::lookup(n)?,
            None => match self
                .doc
                .get("models")
                .and_then(Json::as_arr)
                .and_then(|a| a.first())
                .and_then(Json::as_str)
            {
                Some(n) => ModelId::lookup(n)?,
                None => ModelId::named("llama3-70b"),
            },
        };
        let seed = self.doc.f64_or("seed", 0.0) as u64;
        let w = self
            .doc
            .get("workload")
            .context("scenario needs 'workload'")?;
        let patch = panel.map(|p| &p.patch);
        let class = |j: &Json| -> Result<WorkloadSpec> {
            let merged = match patch {
                Some(p) => j.merged(p),
                None => j.clone(),
            };
            config::parse_workload(model, &merged, seed)
        };
        let mix = match w {
            Json::Arr(classes) => {
                if classes.is_empty() {
                    bail!("workload mix must have at least one class");
                }
                WorkloadMix::new(
                    classes
                        .iter()
                        .map(|c| Ok((c.f64_or("fraction", 1.0), class(c)?)))
                        .collect::<Result<Vec<_>>>()?,
                )
            }
            _ => WorkloadMix::single(class(w)?),
        };
        let total_rate: f64 = mix
            .classes
            .iter()
            .map(|(f, s)| f * s.arrival.rate())
            .sum();
        Ok(mix.scaled(n_requests, total_rate.max(1e-9)))
    }

    /// SLO ladder: the panel's override, else the scenario's `slo` key
    /// (with `auto` resolved against the mix's primary pipeline).
    pub fn slo(&self, panel: Option<&Panel>, mix: &WorkloadMix) -> Result<SloLadder> {
        let name = panel
            .and_then(|p| p.slo.as_deref())
            .unwrap_or_else(|| self.doc.str_or("slo", "auto"));
        config::parse_slo(name, &mix.primary().pipeline)
    }

    /// Figure-specific knobs (the `"extras"` object; empty if absent).
    pub fn extras(&self) -> Json {
        self.doc.get("extras").cloned().unwrap_or_else(Json::obj)
    }

    /// `<key>_fast` / `<key>_full` for this run — the naming convention
    /// scale-dependent `extras` keys use.
    pub fn scaled_key(&self, fast: bool, key: &str) -> String {
        format!("{key}_{}", if self.use_fast(fast) { "fast" } else { "full" })
    }

    /// Strict scalar accessors for `extras`: a missing key is an error,
    /// so a paper-scale run can never silently fall back to toy values.
    pub fn extra_f64(&self, key: &str) -> Result<f64> {
        self.extras()
            .get(key)
            .and_then(Json::as_f64)
            .with_context(|| format!("scenario '{}' needs numeric extras.{key}", self.name))
    }

    pub fn extra_usize(&self, key: &str) -> Result<usize> {
        self.extras()
            .get(key)
            .and_then(Json::as_usize)
            .with_context(|| format!("scenario '{}' needs integer extras.{key}", self.name))
    }

    /// Strict numeric-array accessor: errors on a missing key, an empty
    /// array, or any non-numeric entry (no silent `filter_map` drops).
    pub fn extra_f64_list(&self, key: &str) -> Result<Vec<f64>> {
        let extras = self.extras();
        let arr = extras
            .get(key)
            .and_then(Json::as_arr)
            .with_context(|| format!("scenario '{}' needs array extras.{key}", self.name))?;
        let out: Vec<f64> = arr
            .iter()
            .enumerate()
            .map(|(i, v)| {
                v.as_f64().with_context(|| {
                    format!("scenario '{}': extras.{key}[{i}] is not a number", self.name)
                })
            })
            .collect::<Result<_>>()?;
        if out.is_empty() {
            bail!("scenario '{}': extras.{key} is empty", self.name);
        }
        Ok(out)
    }

    pub fn extra_usize_list(&self, key: &str) -> Result<Vec<usize>> {
        Ok(self
            .extra_f64_list(key)?
            .into_iter()
            .map(|v| v as usize)
            .collect())
    }

    /// Exhaustive reference resolution for `hermes scenario check`:
    /// every roster entry's serving spec must *build* (resolving model,
    /// co-model, model-policy and NPU references down to constructed
    /// clients) at both scales, and every panel's serving overrides,
    /// workload patch and SLO name must parse. A dangling reference
    /// anywhere in the file is an error here rather than a mid-sweep
    /// surprise.
    pub fn check(&self) -> Result<()> {
        // a scenario file must be self-contained: every model it names
        // must be built-in or declared in ITS OWN model_catalog. The
        // registry is process-global, so without this a dangling name
        // would "resolve" whenever another scenario parsed earlier in
        // the same process happened to register it.
        {
            let spec = self.serving(&self.roster[0], self.full.clients)?;
            let mut refs = vec![ModelId::lookup(spec.model)?];
            refs.extend(spec.co_models.iter().copied());
            if let Some(p) = &spec.model_policy {
                refs.extend(p.models());
            }
            for m in refs {
                if !m.is_builtin() && !self.catalog_models.contains(&m) {
                    bail!(
                        "scenario '{}' references model '{m}', which is neither \
                         built-in nor declared in this file's model_catalog \
                         (it only resolves via another scenario's catalog)",
                        self.name
                    );
                }
            }
        }
        for (label, scale) in [("full", &self.full), ("fast", &self.fast)] {
            if scale.rates.is_empty() {
                bail!("scale '{label}' has no rates");
            }
            for (ei, entry) in self.roster.iter().enumerate() {
                for panel in self.panels_or_default() {
                    let ctx = || {
                        format!(
                            "scenario '{}': roster[{ei}], panel '{}', {label} scale",
                            self.name, panel.label
                        )
                    };
                    let spec = self
                        .serving_panel(entry, scale.clients, Some(&panel))
                        .with_context(ctx)?;
                    spec.build().map(drop).with_context(ctx)?;
                    let mix = self.workload(Some(&panel), 8).with_context(ctx)?;
                    self.slo(Some(&panel), &mix).with_context(ctx)?;
                }
            }
        }
        Ok(())
    }

    /// Panels, or a single unlabeled panel when the scenario has none —
    /// callers can always iterate.
    pub fn panels_or_default(&self) -> Vec<Panel> {
        if self.panels.is_empty() {
            vec![Panel {
                label: String::new(),
                patch: Json::obj(),
                slo: None,
                raw: Json::obj(),
            }]
        } else {
            self.panels.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(s: &str) -> Json {
        Json::parse(s).unwrap()
    }

    const MINIMAL: &str = r#"{
        "title": "minimal",
        "model": "llama3-70b", "npu": "h100", "tp": 8,
        "batching": ["continuous", "chunked:256", "disagg:0.6"],
        "perf_model": "roofline",
        "workload": { "trace": "azure-conv" },
        "sweep": { "full": { "clients": 8, "requests_per_client": 30,
                             "rates": [1.0, 2.0] },
                   "fast": { "clients": 2, "requests_per_client": 8,
                             "rates": [1.0] } }
    }"#;

    #[test]
    fn roster_entries_resolve_against_pool_size() {
        let sc = Scenario::from_json("t", doc(MINIMAL)).unwrap();
        assert_eq!(sc.roster.len(), 3);
        assert_eq!(
            sc.roster[0].pool(8),
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 8 }
        );
        assert_eq!(
            sc.roster[1].pool(3),
            PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 256 }, n: 3 }
        );
        assert_eq!(
            sc.roster[2].pool(8),
            PoolSpec::Disaggregated { prefill: 5, decode: 3, local: false }
        );
        // fraction resolves differently at a different scale
        assert_eq!(
            sc.roster[2].pool(32),
            PoolSpec::Disaggregated { prefill: 19, decode: 13, local: false }
        );
    }

    #[test]
    fn roster_string_grammar() {
        assert_eq!(
            RosterEntry::parse("disagg:20P/12D").unwrap(),
            RosterEntry::Fixed(PoolSpec::Disaggregated { prefill: 20, decode: 12, local: false })
        );
        assert_eq!(
            RosterEntry::parse("disagg-local:0.5").unwrap(),
            RosterEntry::DisaggFrac { prefill_frac: 0.5, local: true }
        );
        assert!(RosterEntry::parse("disagg:1.5").is_err());
        assert!(RosterEntry::parse("warp-drive").is_err());
    }

    #[test]
    fn scales_honor_fast_flag() {
        let sc = Scenario::from_json("t", doc(MINIMAL)).unwrap();
        assert_eq!(sc.scale(false).clients, 8);
        if std::env::var("HERMES_FULL").is_err() {
            assert_eq!(sc.scale(true).clients, 2);
            assert_eq!(sc.scale(true).rates, vec![1.0]);
        }
    }

    #[test]
    fn serving_and_workload_build() {
        let sc = Scenario::from_json("t", doc(MINIMAL)).unwrap();
        let spec = sc.serving(&sc.roster[0], 2).unwrap();
        assert_eq!(spec.pool.n_clients(), 2);
        let mix = sc.workload(None, 40).unwrap();
        assert_eq!(mix.n_total(), 40);
        let mut coord = spec.build().unwrap();
        coord.inject(mix.generate());
        coord.run();
        assert!(coord.all_serviced());
    }

    #[test]
    fn workload_mix_and_panels() {
        let sc = Scenario::from_json(
            "t",
            doc(r#"{
                "model": "llama3-70b",
                "batching": ["continuous"],
                "workload": [
                    { "fraction": 0.75, "trace": "azure-conv" },
                    { "fraction": 0.25, "trace": "azure-conv", "pipeline": "rag",
                      "docs": 6, "doc_tokens": 500 }
                ],
                "panels": [
                    { "label": "code", "workload": { "trace": "azure-code" },
                      "slo": "retrieval" }
                ],
                "sweep": { "clients": 2, "requests_per_client": 10, "rates": [1.0] }
            }"#),
        )
        .unwrap();
        let mix = sc.workload(None, 80).unwrap();
        assert_eq!(mix.classes.len(), 2);
        assert_eq!(mix.classes[0].1.n_requests, 60);
        assert_eq!(mix.classes[1].1.n_requests, 20);
        // panel patch applies to every class
        let panel = &sc.panels[0];
        let patched = sc.workload(Some(panel), 8).unwrap();
        for (_, class) in &patched.classes {
            assert_eq!(class.trace, crate::workload::trace::TraceKind::AzureCode);
        }
        // panel SLO override
        let slo = sc.slo(Some(panel), &patched).unwrap();
        assert_eq!(slo.ttft_base, 1.0);
        // default: auto → standard for the regular-dominated mix
        let slo = sc.slo(None, &mix).unwrap();
        assert_eq!(slo.ttft_base, 0.25);
    }

    #[test]
    fn panels_override_migration_pricing() {
        let sc = Scenario::from_json(
            "t",
            doc(r#"{
                "model": "llama3-70b",
                "batching": ["disagg:0.5"],
                "migration": { "granularity": "full", "pool": ["dram"] },
                "workload": { "trace": "azure-conv", "pipeline": "disagg" },
                "panels": [
                    { "label": "layerwise",
                      "migration": { "granularity": "layerwise:40",
                                     "pool": ["dram", "nvme"] } },
                    { "label": "no-staging", "migration": null }
                ],
                "sweep": { "clients": 2, "requests_per_client": 6, "rates": [1.0] }
            }"#),
        )
        .unwrap();
        sc.check().unwrap();
        let base = sc.serving(&sc.roster[0], 2).unwrap();
        assert_eq!(base.migration.as_ref().unwrap().pool.len(), 1);
        let layerwise = sc
            .serving_panel(&sc.roster[0], 2, Some(&sc.panels[0]))
            .unwrap();
        assert_eq!(layerwise.migration.as_ref().unwrap().pool.len(), 2);
        let none = sc
            .serving_panel(&sc.roster[0], 2, Some(&sc.panels[1]))
            .unwrap();
        assert!(none.migration.is_none(), "null removes the key");
        // a dangling tier ref anywhere in the file fails the parse
        let bad = r#"{
            "model": "llama3-70b", "batching": ["disagg:0.5"],
            "migration": { "pool": ["tape"] },
            "workload": { "trace": "azure-conv", "pipeline": "disagg" }
        }"#;
        assert!(Scenario::from_json("bad", doc(bad)).is_err());
    }

    #[test]
    fn check_rejects_cross_scenario_catalog_leakage() {
        use crate::model::ModelId;

        // simulate another scenario's catalog having registered a model
        // earlier in this process
        ModelId::register(crate::hardware::ModelSpec {
            name: "leaktest-9b",
            params: 9e9,
            layers: 30,
            hidden: 4096,
            heads: 32,
            kv_heads: 8,
            d_head: 128,
            bytes_per_param: 1.0,
            decoder: true,
        })
        .unwrap();
        let body = r#""npu": "h100", "tp": 8, "batching": ["continuous"],
            "perf_model": "roofline", "workload": { "trace": "azure-conv" },
            "sweep": { "clients": 1, "requests_per_client": 4, "rates": [1.0] }"#;
        // the name resolves globally, so parsing succeeds…
        let sc = Scenario::from_json(
            "leaky",
            doc(&format!(r#"{{ "model": "leaktest-9b", {body} }}"#)),
        )
        .unwrap();
        // …but the file is not self-contained, and check says so
        let err = sc.check().unwrap_err().to_string();
        assert!(err.contains("leaktest-9b"), "{err}");
        // declaring the same model in the file's own catalog passes
        let sc = Scenario::from_json(
            "selfcontained",
            doc(&format!(
                r#"{{ "model": "leaktest-9b",
                      "model_catalog": [{{ "name": "leaktest-9b", "params": 9e9,
                        "layers": 30, "hidden": 4096, "heads": 32, "kv_heads": 8 }}],
                      {body} }}"#
            )),
        )
        .unwrap();
        sc.check().unwrap();
    }

    #[test]
    fn check_validates_fault_specs() {
        let body = |faults: &str| {
            format!(
                r#"{{ "model": "llama3-70b", "npu": "h100", "tp": 8,
                      "batching": ["continuous"], "perf_model": "roofline",
                      "workload": {{ "trace": "azure-conv" }},
                      "faults": {faults},
                      "sweep": {{ "clients": 2, "requests_per_client": 4,
                                  "rates": [1.0] }} }}"#
            )
        };
        // a well-formed plan parses and survives check
        let sc = Scenario::from_json(
            "faulty",
            doc(&body(
                r#"{"crashes": [{"client": 1, "at": 0.5, "down_for": 2.0}],
                    "stage_failure_prob": 0.1}"#,
            )),
        )
        .unwrap();
        sc.check().unwrap();
        // a crash targeting a client the pool doesn't have is caught at
        // check time (FaultPlan::compile runs inside spec.build())
        let sc = Scenario::from_json(
            "dangling",
            doc(&body(r#"{"crashes": [{"client": 64, "at": 0.5, "down_for": 2.0}]}"#)),
        )
        .unwrap();
        let err = sc.check().unwrap_err();
        assert!(format!("{err:#}").contains("client"), "{err:#}");
        // an out-of-range probability never parses into a runnable spec
        let sc = Scenario::from_json("badprob", doc(&body(r#"{"stage_failure_prob": 2.0}"#)))
            .unwrap();
        assert!(sc.check().is_err());
        // structurally broken fault entries are parse errors
        assert!(Scenario::from_json(
            "noclient",
            doc(&body(r#"{"crashes": [{"at": 0.5, "down_for": 2.0}]}"#)),
        )
        .is_err());
    }

    #[test]
    fn malformed_scenarios_fail_fast() {
        for bad in [
            r#"{"workload": {"trace": "azure-conv"}}"#,
            r#"{"batching": ["quantum"], "workload": {}}"#,
            r#"{"batching": ["continuous"]}"#,
            r#"{"batching": ["continuous"], "workload": {"trace": "alien"}}"#,
        ] {
            assert!(Scenario::from_json("bad", doc(bad)).is_err(), "{bad}");
        }
    }
}
