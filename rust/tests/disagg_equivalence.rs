//! Serial oracle for cluster-level prefill/decode disaggregation (same
//! style as `retirement_equivalence.rs`):
//!
//! * equivalence: the explicit three-stage disaggregated pipeline
//!   (prefill → kv_migration → decode) on a colocated pool — where the
//!   combined client consumes the hand-off in place at zero cost — is
//!   bit-identical to the plain two-stage pipeline (serviced order,
//!   clock, event count, every latency/energy sample), in both
//!   `LoadMode`s, and stays bit-identical when an inert `MigrationSpec`
//!   (granularity + tiered staging pool) is configured;
//! * parallelism: the oracle holds under the `--jobs N` sweep executor —
//!   rate sweeps of both pipelines fingerprint identically at jobs 1
//!   and 2;
//! * pricing: on a genuinely disaggregated pool every request pays
//!   exactly one migration, and the migrated volume matches the regular
//!   pipeline's implicit prefill→decode hand-off byte for byte (same
//!   KV-size formula, same token draws).

use hermes::config::slo::SloLadder;
use hermes::coordinator::{Coordinator, LoadMode};
use hermes::hardware::npu::H100;
use hermes::memory::hierarchy::{TIER_DRAM, TIER_HBM};
use hermes::metrics::RunMetrics;
use hermes::network::Granularity;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{MigrationSpec, PoolSpec, ServingSpec};
use hermes::sim::{driver, parallel};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadMix, WorkloadSpec};

fn colocated_spec() -> ServingSpec {
    ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
    )
    .with_seed(83)
}

fn mix(pipeline: Pipeline, n: usize) -> WorkloadMix {
    WorkloadMix::single(
        WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, 4.0)
            .with_seed(89)
            .with_pipeline(pipeline),
    )
}

fn run(spec: &ServingSpec, mix: &WorkloadMix, mode: LoadMode) -> (Coordinator, RunMetrics) {
    let mut coord = spec.build().unwrap();
    coord.load_mode = mode;
    coord.inject(mix.generate());
    coord.run();
    let m = RunMetrics::collect(&coord, &SloLadder::standard());
    (coord, m)
}

fn assert_bit_identical(a: &(Coordinator, RunMetrics), b: &(Coordinator, RunMetrics)) {
    let ((ca, ma), (cb, mb)) = (a, b);
    assert!(ca.all_serviced(), "serviced {}", ca.serviced.len());
    assert!(cb.all_serviced(), "serviced {}", cb.serviced.len());
    assert_eq!(ca.serviced, cb.serviced, "completion order diverged");
    assert_eq!(ca.failed, cb.failed, "failure set diverged");
    assert_eq!(ca.clock, cb.clock);
    assert_eq!(ma.events, mb.events);
    assert_eq!(ma.n_requests, mb.n_requests);
    assert_eq!(ma.makespan, mb.makespan);
    assert_eq!(ma.n_serviced, mb.n_serviced);
    assert_eq!(ma.n_failed, mb.n_failed);
    assert_eq!(ma.ttft_samples, mb.ttft_samples);
    assert_eq!(ma.tpot_samples, mb.tpot_samples);
    assert_eq!(ma.e2e_samples, mb.e2e_samples);
    assert_eq!(ma.transfer_bytes, mb.transfer_bytes);
    assert_eq!(ma.energy_joules, mb.energy_joules);
    assert_eq!(ma.goodput_frac, mb.goodput_frac);
    assert_eq!(ma.throughput_tok_s, mb.throughput_tok_s);
}

/// An inert migration config: pricing knobs that must not change a
/// colocated run, because the combined client consumes the hand-off
/// before the coordinator's migration path ever sees it.
fn inert_migration() -> MigrationSpec {
    MigrationSpec {
        granularity: Some(Granularity::Full),
        pool: vec![TIER_HBM, TIER_DRAM],
    }
}

#[test]
fn colocated_disagg_is_bit_identical_to_regular_both_load_modes() {
    let w_reg = mix(Pipeline::Regular, 60);
    let w_dis = mix(Pipeline::Disagg, 60);
    for mode in [LoadMode::Incremental, LoadMode::FullScan] {
        let reg = run(&colocated_spec(), &w_reg, mode);
        let dis = run(&colocated_spec(), &w_dis, mode);
        assert_bit_identical(&reg, &dis);
        // the hand-off stage never reaches the network on a colocated
        // pool: both pipelines price the same (zero) migrations
        assert_eq!(dis.0.stats.transfers, reg.0.stats.transfers);

        // configuring migration pricing is inert here — the kv_migration
        // stage is consumed inside the client, so granularity and the
        // staging pool have nothing to price
        let priced = run(&colocated_spec().with_migration(inert_migration()), &w_dis, mode);
        assert_bit_identical(&reg, &priced);
    }
}

#[test]
fn disagg_oracle_holds_across_job_counts() {
    let spec = colocated_spec();
    let slo = SloLadder::standard();
    let w_reg = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 30, 4.0).with_seed(89);
    let w_dis = w_reg.clone().with_pipeline(Pipeline::Disagg);
    let rates = [2.0, 4.0];
    let fingerprint = |points: &[driver::SweepPoint]| -> Vec<String> {
        points
            .iter()
            .map(|p| format!("rate={:?} slo_ok={:?} metrics={:?}", p.rate, p.slo_ok, p.metrics))
            .collect()
    };

    parallel::set_jobs(1);
    let reg_serial = fingerprint(&driver::sweep_rates(&spec, &w_reg, &slo, &rates).unwrap());
    let dis_serial = fingerprint(&driver::sweep_rates(&spec, &w_dis, &slo, &rates).unwrap());
    assert_eq!(reg_serial, dis_serial, "serial oracle broken at jobs=1");

    parallel::set_jobs(2);
    let reg_par = fingerprint(&driver::sweep_rates(&spec, &w_reg, &slo, &rates).unwrap());
    let dis_par = fingerprint(&driver::sweep_rates(&spec, &w_dis, &slo, &rates).unwrap());
    parallel::set_jobs(1);
    assert_eq!(reg_par, reg_serial, "regular sweep diverged at jobs=2");
    assert_eq!(dis_par, dis_serial, "disagg sweep diverged at jobs=2");
}

#[test]
fn disaggregated_pool_prices_migrations_and_completes() {
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 1, decode: 1, local: false },
    )
    .with_migration(MigrationSpec {
        granularity: Some(Granularity::Layerwise { layers: 80 }),
        pool: vec![TIER_DRAM],
    })
    .with_seed(97);

    let dis = run(&spec, &mix(Pipeline::Disagg, 40), LoadMode::Incremental);
    assert!(dis.0.all_serviced(), "serviced {}", dis.0.serviced.len());
    assert_eq!(dis.0.stats.transfers, 40, "one explicit migration per request");
    assert!(dis.0.stats.transfer_bytes > 0.0);
    assert!(dis.0.stats.transfer_seconds > 0.0, "staged layerwise hand-off takes time");

    // the regular pipeline on the same disaggregated pool pays the same
    // implicit prefill→decode hand-off: identical count and — since both
    // use the full-prefix KV-size formula on the same token draws —
    // identical total bytes
    let reg = run(&spec, &mix(Pipeline::Regular, 40), LoadMode::Incremental);
    assert!(reg.0.all_serviced());
    assert_eq!(reg.0.stats.transfers, 40);
    assert_eq!(dis.0.stats.transfer_bytes, reg.0.stats.transfer_bytes);
}
