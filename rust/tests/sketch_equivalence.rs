//! Differential suite for the streaming metrics sink
//! (`metrics::MetricsSink`, the `--metrics sketch` mode): streaming
//! completions through mergeable quantile sketches is a memory
//! decision with a *bounded-error* contract, never an unbounded one.
//! Same style as `pool_equivalence` / `shard_equivalence`, extended
//! where bit-exactness is impossible by construction:
//!
//! * counters, token sums and extremes are **bit-exact** against the
//!   retained-records oracle: n/serviced/failed, makespan, events,
//!   throughput, goodput, energy, per-summary min/max (the sink tracks
//!   them exactly; token sums are integer-valued f64, so accumulation
//!   order cannot shift them);
//! * percentiles carry the documented relative-error bound: sketch
//!   p50/p90/p99 within `SKETCH_ALPHA` (1%) of the exact oracle's, on
//!   TTFT, TPOT and E2E alike (docs/performance.md "Streaming
//!   metrics");
//! * sharding is invisible: `--shards 2/4` merge per-domain sketches
//!   in domain order, and because the sketch stores integer counts in
//!   integer bins, the merged quantiles are **bit-identical** to the
//!   serial sketch run's — the PR 8 bit-exactness machinery applies to
//!   the sketch path unchanged;
//! * exact mode keeps its raw sample vecs; sketch mode never
//!   allocates them.

use hermes::config::slo::SloLadder;
use hermes::coordinator::shard::{run_sharded, Arrivals};
use hermes::metrics::{MetricsSink, RunMetrics};
use hermes::scenario::Scenario;
use hermes::util::stats::SKETCH_ALPHA;

/// Run `bench_llm_1m` at fast scale (the 1M tier's shape at 10k
/// requests) under the given metrics mode and shard count, exactly as
/// the bench harness wires it: streamed arrivals, retirement on, and —
/// sketch mode — a per-coordinator `MetricsSink`.
fn run_tier(sketch: bool, shards: usize) -> RunMetrics {
    let sc = Scenario::load("bench_llm_1m").unwrap();
    let scale = sc.scale(true);
    let entry = sc.roster.first().unwrap();
    let spec = sc.serving(entry, scale.clients).unwrap();
    let rate = scale.rates[0];
    let n = scale.clients * scale.requests_per_client;
    let mix = sc
        .workload(None, n)
        .unwrap()
        .scaled(n, rate * spec.pool.n_clients() as f64);
    let slo = SloLadder::standard();
    if shards == 1 {
        let mut coord = spec.build().unwrap();
        coord.retire = true;
        if sketch {
            coord.sink = Some(MetricsSink::new(slo));
        }
        coord.stream(&mix);
        coord.run();
        RunMetrics::collect(&coord, &slo)
    } else {
        let build = || {
            let mut c = spec.build()?;
            c.retire = true;
            if sketch {
                c.sink = Some(MetricsSink::new(slo));
            }
            Ok(c)
        };
        let out = run_sharded(build, Arrivals::Stream(&mix), shards).unwrap();
        RunMetrics::collect_outcome(&out, &slo)
    }
}

/// |sketch − exact| ≤ α·|exact| at every reported percentile, with the
/// summary's count/min/max exactly equal (the sink tracks extremes
/// outside the bins).
fn assert_summary_within_alpha(
    sk: &hermes::util::stats::Summary,
    ex: &hermes::util::stats::Summary,
    label: &str,
) {
    assert_eq!(sk.n, ex.n, "{label}: sample count diverged");
    assert_eq!(sk.min.to_bits(), ex.min.to_bits(), "{label}: min diverged");
    assert_eq!(sk.max.to_bits(), ex.max.to_bits(), "{label}: max diverged");
    for (q, s, e) in [("p50", sk.p50, ex.p50), ("p90", sk.p90, ex.p90), ("p99", sk.p99, ex.p99)] {
        assert!(
            (s - e).abs() <= SKETCH_ALPHA * e.abs() + 1e-12,
            "{label} {q}: sketch {s} vs exact {e} exceeds α={SKETCH_ALPHA}"
        );
    }
    // the sink's mean comes from a running f64 sum whose accumulation
    // order matches the serial fold, so it agrees far beyond α
    assert!(
        (sk.mean - ex.mean).abs() <= 1e-9 * ex.mean.abs() + 1e-12,
        "{label}: mean {} vs {}",
        sk.mean,
        ex.mean
    );
}

#[test]
fn sketch_percentiles_match_exact_oracle_serial_and_sharded() {
    if std::env::var("HERMES_FULL").is_ok() {
        return; // smoke suite: don't inherit paper scale
    }
    let exact = run_tier(false, 1);
    assert!(exact.exact, "retained-records mode is the oracle");
    assert!(exact.n_serviced > 0);
    assert!(!exact.e2e_samples.is_empty(), "exact mode keeps raw CDF samples");

    let mut sketch_runs = Vec::new();
    for shards in [1, 2, 4] {
        let sk = run_tier(true, shards);
        assert!(!sk.exact, "sink mode reports metrics=sketch (shards={shards})");
        // raw sample retention is gated off — streaming runs never
        // allocate the per-request vecs
        assert!(sk.e2e_samples.is_empty() && sk.ttft_samples.is_empty());
        assert!(sk.tpot_samples.is_empty());
        // counters and running sums are bit-exact against the oracle
        assert_eq!(sk.n_requests, exact.n_requests, "shards={shards}");
        assert_eq!(sk.n_serviced, exact.n_serviced, "shards={shards}");
        assert_eq!(sk.n_failed, exact.n_failed, "shards={shards}");
        assert_eq!(sk.n_no_first_token, exact.n_no_first_token, "shards={shards}");
        assert_eq!(sk.events, exact.events, "shards={shards}");
        assert_eq!(sk.makespan.to_bits(), exact.makespan.to_bits(), "shards={shards}");
        // token counts are integer-valued f64: order-independent sums,
        // so throughput and goodput agree exactly in every mode
        assert_eq!(
            sk.throughput_tok_s.to_bits(),
            exact.throughput_tok_s.to_bits(),
            "shards={shards}"
        );
        assert_eq!(sk.goodput_frac.to_bits(), exact.goodput_frac.to_bits(), "shards={shards}");
        assert_eq!(sk.energy_joules.to_bits(), exact.energy_joules.to_bits(), "shards={shards}");
        // percentiles: the bounded-error contract
        assert_summary_within_alpha(&sk.ttft, &exact.ttft, "ttft");
        assert_summary_within_alpha(&sk.tpot, &exact.tpot, "tpot");
        assert_summary_within_alpha(&sk.e2e, &exact.e2e, "e2e");
        sketch_runs.push(sk);
    }

    // across shard counts the sketch path is bit-identical: integer
    // bin counts merge exactly, in deterministic domain order
    let serial = &sketch_runs[0];
    for (i, sk) in sketch_runs.iter().enumerate().skip(1) {
        let shards = [1, 2, 4][i];
        for (s, e, label) in
            [(&sk.ttft, &serial.ttft, "ttft"), (&sk.tpot, &serial.tpot, "tpot"), (&sk.e2e, &serial.e2e, "e2e")]
        {
            assert_eq!(s.n, e.n, "{label}: n diverged at shards={shards}");
            for (q, a, b) in [("p50", s.p50, e.p50), ("p90", s.p90, e.p90), ("p99", s.p99, e.p99)]
            {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{label} {q}: sharded sketch diverged from serial sketch at shards={shards}"
                );
            }
            assert_eq!(s.min.to_bits(), e.min.to_bits());
            assert_eq!(s.max.to_bits(), e.max.to_bits());
        }
    }
}

#[test]
fn sketch_sink_memory_is_o1_in_request_count() {
    if std::env::var("HERMES_FULL").is_ok() {
        return;
    }
    // fold 1k vs 100k synthetic completions through sinks: the sketch
    // state must not grow with request count (bins depend only on the
    // value range), which is the whole point of the 100M tier
    use hermes::model::ModelId;
    use hermes::sim::time::SimTime;
    use hermes::workload::request::CompletionRecord;
    let slo = SloLadder::standard();
    let model = ModelId::named("llama3-70b");
    let footprint = |n: usize| {
        let mut sink = MetricsSink::new(slo);
        for i in 0..n {
            // TTFTs spanning three decades (10ms .. ~10s), deterministic
            let t1 = 0.01 + ((i as u64 * 2654435761) % 997) as f64 * 0.01;
            let arrive = i as f64 * 0.001;
            let r = CompletionRecord {
                id: i as u64,
                model,
                arrival: SimTime::from_secs(arrive),
                finished: Some(SimTime::from_secs(arrive + t1 + 1.0)),
                first_token_time: Some(SimTime::from_secs(arrive + t1)),
                last_token_time: Some(SimTime::from_secs(arrive + t1 + 0.9)),
                first_response_time: None,
                prompt_tokens: 128,
                output_tokens: 64,
                decoded: 64,
                branches: 1,
                prior_decoded: 0,
                failed: false,
            };
            sink.fold(&r);
        }
        assert_eq!(sink.n_completed(), n as u64);
        sink.bytes_est()
    };
    let small = footprint(1_000);
    let large = footprint(100_000);
    assert!(
        large <= small * 2,
        "sink grew with request count: {small} bytes at 1k vs {large} at 100k"
    );
    assert!(large < 256 * 1024, "sink footprint {large} exceeds the O(1) budget");
}
