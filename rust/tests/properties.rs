//! Property-based tests over randomized serving configurations: request
//! conservation, KV-capacity safety, clock monotonicity (implied by
//! completion), metric sanity, and router balance — the coordinator
//! invariants the paper's Algorithm 1 must uphold for ANY configuration.

use hermes::config::slo::SloLadder;
use hermes::coordinator::{LoadMetric, RoutePolicy};
use hermes::hardware::npu::H100;
use hermes::metrics::RunMetrics;
use hermes::prop_assert;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use hermes::util::prop::check;
use hermes::util::rng::Pcg;
use hermes::workload::trace::{Pipeline, Reasoning, TraceKind, WorkloadSpec};

/// Draw a random but valid serving spec + workload.
fn random_case(rng: &mut Pcg) -> (ServingSpec, WorkloadSpec) {
    let tp = *rng.choose(&[2usize, 4, 8]);
    let n = rng.range_usize(1, 5);
    let pool = match rng.below(6) {
        0 => PoolSpec::Combined { kind: BatchingKind::Static, n },
        1 => PoolSpec::Combined { kind: BatchingKind::Continuous, n },
        2 => PoolSpec::Combined {
            kind: BatchingKind::Chunked { chunk: *rng.choose(&[128usize, 512, 2048]) },
            n,
        },
        3 => PoolSpec::Combined { kind: BatchingKind::Mixed, n },
        4 => PoolSpec::Disaggregated {
            prefill: rng.range_usize(1, 4),
            decode: rng.range_usize(1, 4),
            local: false,
        },
        _ => PoolSpec::Disaggregated {
            prefill: rng.range_usize(1, 3),
            decode: rng.range_usize(1, 3),
            local: true,
        },
    };
    let route = match rng.below(3) {
        0 => RoutePolicy::RoundRobin,
        1 => RoutePolicy::LoadBased(*rng.choose(&[
            LoadMetric::InputLen,
            LoadMetric::OutputLen,
            LoadMetric::KvSize,
            LoadMetric::TokensLeft,
        ])),
        _ => RoutePolicy::HeavyLight {
            metric: LoadMetric::TokensLeft,
            threshold_tokens: 1024,
            heavy_frac: 0.5,
        },
    };
    let spec = ServingSpec::new("llama3-70b", H100, tp, pool)
        .with_perf(PerfBackend::Poly)
        .with_route(route)
        .with_seed(rng.next_u64());

    let trace = if rng.chance(0.5) { TraceKind::AzureConv } else { TraceKind::AzureCode };
    let reasoning = if rng.chance(0.2) {
        Reasoning::MultiPath { scale: 2.0, branches: rng.range_usize(2, 5) }
    } else {
        Reasoning::None
    };
    let n_req = rng.range_usize(5, 30);
    let rate = rng.range_f64(0.5, 10.0);
    let workload = WorkloadSpec::new("llama3-70b", trace, n_req, rate)
        .with_pipeline(Pipeline::Regular)
        .with_reasoning(reasoning)
        .with_seed(rng.next_u64());
    (spec, workload)
}

#[test]
fn conservation_every_request_serviced_exactly_once() {
    check(0xC0DE, 25, |rng| {
        let (spec, workload) = random_case(rng);
        let mut coord = spec.build().map_err(|e| e.to_string())?;
        let reqs = workload.generate(0);
        let n = reqs.len();
        coord.inject(reqs);
        coord.run();
        prop_assert!(
            coord.serviced.len() + coord.failed.len() == n,
            "lost requests: serviced {} + failed {} != {n} ({})",
            coord.serviced.len(),
            coord.failed.len(),
            spec.pool.label()
        );
        // no duplicates in serviced
        let mut ids: Vec<u64> = coord.serviced.clone();
        ids.sort();
        ids.dedup();
        prop_assert!(ids.len() == coord.serviced.len(), "duplicate completions");
        Ok(())
    });
}

#[test]
fn latency_metrics_are_internally_consistent() {
    check(0xFACE, 15, |rng| {
        let (spec, workload) = random_case(rng);
        let mut coord = spec.build().map_err(|e| e.to_string())?;
        coord.inject(workload.generate(0));
        coord.run();
        let m = RunMetrics::collect(&coord, &SloLadder::standard());
        for id in &coord.serviced {
            let r = &coord.pool[id];
            let ttft = r.ttft().ok_or("missing ttft")?;
            let e2e = r.e2e_latency().ok_or("missing e2e")?;
            prop_assert!(ttft >= 0.0, "negative ttft");
            prop_assert!(e2e + 1e-9 >= ttft, "e2e {e2e} < ttft {ttft}");
            if let Some(tpot) = r.tpot() {
                prop_assert!(tpot >= 0.0, "negative tpot");
            }
            prop_assert!(r.decoded >= r.output_tokens, "incomplete decode");
        }
        prop_assert!(m.e2e.p99 + 1e-12 >= m.e2e.p50, "p99 < p50");
        prop_assert!(m.makespan > 0.0, "zero makespan");
        prop_assert!(m.energy_joules > 0.0, "zero energy");
        Ok(())
    });
}

#[test]
fn kv_capacity_never_exceeded() {
    // stress admission with reasoning workloads against small KV budgets
    check(0xCAFE, 12, |rng| {
        let tp = *rng.choose(&[2usize, 4]);
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            tp,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 },
        )
        .with_perf(PerfBackend::Poly);
        let workload = WorkloadSpec::new(
            "llama3-70b",
            TraceKind::AzureConv,
            rng.range_usize(5, 15),
            rng.range_f64(1.0, 4.0),
        )
        .with_reasoning(Reasoning::MultiPath { scale: 8.0, branches: 8 })
        .with_seed(rng.next_u64());
        let mut coord = spec.build().map_err(|e| e.to_string())?;
        coord.inject(workload.generate(0));
        coord.run();
        // finishing at all (no deadlock/panic) plus conservation is the
        // observable invariant; capacity breaches would panic in debug
        prop_assert!(coord.all_serviced(), "deadlocked under KV pressure");
        Ok(())
    });
}

#[test]
fn round_robin_balances_identical_clients() {
    check(0xBA1A, 10, |rng| {
        let n = rng.range_usize(2, 6);
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n },
        )
        .with_perf(PerfBackend::Poly)
        .with_route(RoutePolicy::RoundRobin);
        let n_req = n * rng.range_usize(8, 15);
        let workload = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n_req, 2.0)
            .with_seed(rng.next_u64());
        let mut coord = spec.build().map_err(|e| e.to_string())?;
        coord.inject(workload.generate(0));
        coord.run();
        let served: Vec<u64> = coord.clients.iter().map(|c| c.stats().requests_served).collect();
        let per = n_req as f64 / n as f64;
        for (i, s) in served.iter().enumerate() {
            prop_assert!(
                (*s as f64 - per).abs() <= 1.0,
                "client {i} served {s}, expected ~{per} (round robin)"
            );
        }
        Ok(())
    });
}

#[test]
fn energy_scales_with_work() {
    check(0xE4E4, 8, |rng| {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        )
        .with_perf(PerfBackend::Poly);
        let seed = rng.next_u64();
        let small = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 10, 4.0).with_seed(seed);
        let big = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 40, 4.0).with_seed(seed);
        let slo = SloLadder::standard();
        let ms = hermes::sim::driver::run(&spec, &small, &slo).map_err(|e| e.to_string())?;
        let mb = hermes::sim::driver::run(&spec, &big, &slo).map_err(|e| e.to_string())?;
        prop_assert!(
            mb.energy_joules > ms.energy_joules,
            "4x work should cost more energy ({} vs {})",
            mb.energy_joules,
            ms.energy_joules
        );
        Ok(())
    });
}

#[test]
fn json_roundtrips_arbitrary_documents() {
    use hermes::util::json::Json;
    fn gen(rng: &mut Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 1e6).round() / 64.0),
            3 => {
                let n = rng.range_usize(0, 12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            *rng.choose(&['a', 'ß', '"', '\\', '\n', '\t', '雪', 'z', ' '])
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.range_usize(0, 5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for i in 0..rng.range_usize(0, 5) {
                    o.set(&format!("k{i}"), gen(rng, depth - 1));
                }
                o
            }
        }
    }
    check(0x7501, 200, |rng| {
        let doc = gen(rng, 3);
        let compact = Json::parse(&doc.to_string()).map_err(|e| e.to_string())?;
        let pretty = Json::parse(&doc.to_pretty()).map_err(|e| e.to_string())?;
        prop_assert!(compact == doc, "compact mismatch: {}", doc.to_string());
        prop_assert!(pretty == doc, "pretty mismatch");
        Ok(())
    });
}

#[test]
fn chunked_scheduler_never_exceeds_token_budget() {
    use hermes::memory::hierarchy::KvManager;
    use hermes::scheduler::{LlmSched, Packing, RequestPool, SchedConfig};
    use hermes::workload::request::{Request, Stage};

    check(0xC4D6, 30, |rng| {
        let chunk = *rng.choose(&[64usize, 256, 512, 2048]);
        let mut sched = LlmSched::new(
            BatchingKind::Chunked { chunk },
            Packing::Fcfs,
            SchedConfig::default(),
        );
        let mut pool = RequestPool::new();
        let mut kv = KvManager::new(1e9);
        for id in 0..rng.range_usize(1, 12) as u64 {
            let r = Request::new(
                id,
                "llama3-70b",
                hermes::sim::SimTime::from_secs(id as f64 * 0.001),
                vec![Stage::Prefill, Stage::Decode],
                rng.range_usize(16, 6000),
                rng.range_usize(1, 64),
            );
            sched.enqueue(id);
            pool.insert(id, r);
        }
        // drive to completion, checking the budget every step
        for _ in 0..200_000 {
            let plan = match sched.plan(&pool, &mut kv) {
                Some(p) => p,
                None => break,
            };
            let dec_tokens: usize =
                plan.decode.iter().map(|id| pool[id].decode_seqs()).sum();
            prop_assert!(
                plan.prefill_tokens() + dec_tokens <= chunk.max(dec_tokens),
                "chunk budget exceeded: {} prefill + {} decode > {}",
                plan.prefill_tokens(),
                dec_tokens,
                chunk
            );
            for (id, n) in &plan.prefill {
                pool.get_mut(id).unwrap().prefilled += n;
            }
            let mut done = Vec::new();
            for id in &plan.decode {
                let r = pool.get_mut(id).unwrap();
                r.decoded += 1;
                if r.decode_complete() {
                    done.push(*id);
                }
            }
            for id in done {
                if let Some(res) = sched.remove(id) {
                    kv.release(res);
                }
            }
        }
        prop_assert!(
            pool.values().all(|r| r.decode_complete()),
            "chunked scheduler failed to drain"
        );
        Ok(())
    });
}
