//! Differential suite for the parallel sweep executor
//! (`sim::parallel`, the `--jobs N` worker pool): parallel dispatch is
//! a scheduling decision, never a semantic one. Same style as
//! `pool_equivalence` / `retirement_equivalence`:
//!
//! * bench: for jobs ∈ {1, 2, 4}, every deterministic `BenchRun` field
//!   of every row (shipping config and all baselines) is identical to
//!   the serial run — only the wall-clock timing fields
//!   (`wall_s` / `events_per_s` / `sim_rate`) may differ, since they
//!   measure the machine, not the simulation;
//! * sweeps: a `compare_scenario` panel (roster × rates, the flattened
//!   fan-out in `scenario::runner::sweep_at`) reproduces the serial
//!   labels, rates, SLO verdicts, every metric sample, and the
//!   cross-strategy winners at jobs ∈ {2, 4};
//! * serviced order: identical coordinators run on concurrent workers
//!   service requests in exactly the serial order;
//! * determinism: repeated parallel runs are identical to each other.

use hermes::bench::{self, Baseline, BenchResult, BenchRun, MetricsOverride};
use hermes::experiments::common::{self, StrategyResult};
use hermes::scenario::Scenario;
use hermes::sim::parallel;
use hermes::util::json::Json;

/// Every deterministic field of a [`BenchRun`] — everything except the
/// wall-clock-derived `wall_s` / `events_per_s` / `sim_rate`. Debug
/// formatting of f64 is exact (shortest round-trip), so string equality
/// here is bit equality.
fn deterministic_fields(b: &BenchRun) -> String {
    format!(
        "events={} peak_queue={} peak_inflight={} n_requests={} n_serviced={} \
         n_clients={} makespan_s={:?} throughput_tok_s={:?} pool_reads={} \
         pool_writes={} pool_slots={} pool_peak_resident={} \
         peak_resident_slots={} resident_bytes_est={} retired={} \
         metrics_bytes_est={} metrics_sketch={} \
         transfers={} transfer_bytes={:?} domains={}",
        b.events,
        b.peak_queue,
        b.peak_inflight,
        b.n_requests,
        b.n_serviced,
        b.n_clients,
        b.makespan_s,
        b.throughput_tok_s,
        b.pool_reads,
        b.pool_writes,
        b.pool_slots,
        b.pool_peak_resident,
        b.peak_resident_slots,
        b.resident_bytes_est,
        b.retired,
        b.metrics_bytes_est,
        b.metrics_sketch,
        b.transfers,
        b.transfer_bytes,
        b.domains,
    )
}

fn assert_rows_identical(serial: &[BenchResult], other: &[BenchResult], jobs: usize) {
    assert_eq!(serial.len(), other.len());
    for (a, b) in serial.iter().zip(other) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.exec, b.exec, "{}: exec mode diverged at jobs={jobs}", a.name);
        let pairs = [
            (Some(&a.incremental), Some(&b.incremental), "incremental"),
            (a.baseline.as_ref(), b.baseline.as_ref(), "full_scan"),
            (a.map_pool.as_ref(), b.map_pool.as_ref(), "map_pool"),
            (a.retained.as_ref(), b.retained.as_ref(), "retained"),
            (a.sharded.as_ref(), b.sharded.as_ref(), "sharded"),
        ];
        for (ra, rb, which) in pairs {
            assert_eq!(
                ra.is_some(),
                rb.is_some(),
                "{}: {which} baseline presence diverged at jobs={jobs}",
                a.name
            );
            if let (Some(ra), Some(rb)) = (ra, rb) {
                assert_eq!(
                    deterministic_fields(ra),
                    deterministic_fields(rb),
                    "{}: {which} run diverged at jobs={jobs}",
                    a.name
                );
            }
        }
    }
}

#[test]
fn bench_rows_are_bit_identical_across_job_counts() {
    if std::env::var("HERMES_FULL").is_ok() {
        return; // smoke test: don't inherit paper scale
    }
    // 50k tier exercises all three speed baselines at fast scale; the
    // 1M tier adds the streamed/retired mode and its retained baseline
    let names = vec!["bench_llm_50k".to_string(), "bench_llm_1m".to_string()];
    let serial =
        bench::run_scenarios(&names, true, Baseline::Auto, 1, 1, MetricsOverride::Auto).unwrap();
    for jobs in [2, 4] {
        let parallel =
            bench::run_scenarios(&names, true, Baseline::Auto, jobs, 1, MetricsOverride::Auto)
                .unwrap();
        assert_rows_identical(&serial, &parallel, jobs);
    }
    // repeated parallel runs are identical to each other, not just to
    // the oracle
    let again =
        bench::run_scenarios(&names, true, Baseline::Auto, 4, 1, MetricsOverride::Auto).unwrap();
    assert_rows_identical(&serial, &again, 4);
}

#[test]
fn bench_json_rows_carry_jobs_and_aggregate_columns() {
    if std::env::var("HERMES_FULL").is_ok() {
        return;
    }
    let names = vec!["bench_llm_50k".to_string()];
    let results =
        bench::run_scenarios(&names, true, Baseline::Auto, 2, 1, MetricsOverride::Auto).unwrap();
    let doc = Json::parse(&bench::to_json(&results, 2, 1.25).to_pretty()).unwrap();
    let rows = doc.as_arr().unwrap();
    assert_eq!(rows[0].at(&["jobs"]).and_then(|j| j.as_f64()), Some(2.0));
    let agg = rows.last().unwrap();
    assert_eq!(agg.at(&["aggregate", "jobs"]).and_then(|j| j.as_f64()), Some(2.0));
    let events = agg.at(&["aggregate", "events"]).and_then(|j| j.as_f64()).unwrap();
    let eps = agg
        .at(&["aggregate", "aggregate_events_per_s"])
        .and_then(|j| j.as_f64())
        .unwrap();
    assert!(events > 0.0);
    assert!((eps - events / 1.25).abs() < 1e-6 * events);
}

fn mini_scenario() -> Scenario {
    Scenario::from_json(
        "parallel-mini",
        Json::parse(
            r#"{
            "model": "llama3-70b", "npu": "h100", "tp": 8,
            "batching": ["static", "continuous", "chunked:512"],
            "perf_model": "roofline",
            "workload": { "trace": "azure-conv" },
            "sweep": { "clients": 2, "requests_per_client": 5, "rates": [1.0, 4.0] }
        }"#,
        )
        .unwrap(),
    )
    .unwrap()
}

/// Full-fidelity view of a panel sweep: label, rate, SLO verdict and
/// every metric field (Debug formatting of f64 is exact — shortest
/// round-trip — so this is a bit-level comparison of every latency and
/// energy sample summary).
fn sweep_fingerprint(results: &[StrategyResult]) -> Vec<String> {
    results
        .iter()
        .map(|r| {
            let points: Vec<String> = r
                .points
                .iter()
                .map(|p| format!("rate={:?} slo_ok={:?} metrics={:?}", p.rate, p.slo_ok, p.metrics))
                .collect();
            format!("{}: {}", r.label, points.join(" | "))
        })
        .collect()
}

#[test]
fn compare_scenario_panel_is_bit_identical_across_job_counts() {
    let sc = mini_scenario();
    parallel::set_jobs(1);
    let serial = common::compare_scenario(&sc, None, true).unwrap();
    let serial_fp = sweep_fingerprint(&serial);
    let serial_winners = common::winners(&serial);
    // the roster × rates grid (3 × 2) exercises the flattened fan-out
    assert_eq!(serial.len(), 3);
    assert!(serial.iter().all(|r| r.points.len() == 2));
    for jobs in [2, 4] {
        parallel::set_jobs(jobs);
        let par = common::compare_scenario(&sc, None, true).unwrap();
        assert_eq!(sweep_fingerprint(&par), serial_fp, "diverged at jobs={jobs}");
        assert_eq!(common::winners(&par), serial_winners, "winners diverged at jobs={jobs}");
    }
    // repeated parallel runs agree with each other
    parallel::set_jobs(4);
    let again = common::compare_scenario(&sc, None, true).unwrap();
    assert_eq!(sweep_fingerprint(&again), serial_fp);
    parallel::set_jobs(1);
}

#[test]
fn parallel_workers_reproduce_serial_serviced_order() {
    use hermes::config::slo::SloLadder;
    use hermes::hardware::npu::H100;
    use hermes::scheduler::BatchingKind;
    use hermes::sim::builder::{PoolSpec, ServingSpec};
    use hermes::workload::trace::{TraceKind, WorkloadSpec};

    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
    );
    let w = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 40, 2.0).with_seed(7);
    let slo = SloLadder::standard();
    let run = |_: usize| {
        let mut coord = spec.build().unwrap();
        coord.inject(w.generate(0));
        coord.run();
        let m = hermes::metrics::RunMetrics::collect(&coord, &slo);
        (coord.serviced.clone(), coord.failed.clone(), format!("{:?}", m))
    };
    let serial = run(0);
    assert!(!serial.0.is_empty());
    // four identical simulations racing on four workers: each must
    // service in exactly the serial order, with identical metrics
    for outcome in parallel::run(4, 4, run) {
        assert_eq!(outcome, serial);
    }
}
