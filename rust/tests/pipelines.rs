//! Multi-stage pipeline integration (Fig 1's three request shapes):
//! RAG, KV-retrieval and guarded pipelines flowing through heterogeneous
//! clients under every batching strategy, with stage-level assertions.

use hermes::config::slo::SloLadder;
use hermes::hardware::npu::{A100, GRACE_CPU, H100};
use hermes::memory::storage::{KvScenario, StorageConfig};
use hermes::metrics::RunMetrics;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{
    KvRetrievalSpec, PerfBackend, PoolSpec, RagSpec, ServingSpec,
};
use hermes::workload::request::{KvParams, RagParams, Stage};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

fn base_spec(pool: PoolSpec) -> ServingSpec {
    ServingSpec::new("llama3-70b", H100, 4, pool).with_perf(PerfBackend::Poly)
}

fn conv(n: usize, rate: f64) -> WorkloadSpec {
    WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, rate).with_seed(21)
}

#[test]
fn rag_pipeline_grows_prompts_before_prefill() {
    let rag = RagParams { docs: 6, doc_tokens: 500, ..Default::default() };
    let spec = base_spec(PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 }).with_rag(
        RagSpec {
            count: 1,
            embed_model: hermes::hardware::models::E5_BASE,
            embed_npu: A100,
            retrieval_npu: GRACE_CPU,
            ivf: Default::default(),
            max_batch: 0,
        },
    );
    let mut coord = spec.build().unwrap();
    let w = conv(25, 5.0).with_pipeline(Pipeline::Rag(rag));
    coord.inject(w.generate(0));
    coord.run();
    assert!(coord.all_serviced());
    for id in &coord.serviced {
        let r = &coord.pool[id];
        // every prompt gained the retrieved context
        assert!(r.prompt_tokens >= 3000, "req {id}: {}", r.prompt_tokens);
        assert!(r.prefill_complete() && r.decode_complete());
        // three stage records: rag, prefill+decode (combined), …
        assert!(r.records.len() >= 2, "req {id}: {:?}", r.records);
        assert_eq!(r.stages[0], Stage::Rag(rag));
    }
}

#[test]
fn kv_retrieval_hits_skip_prefill_misses_recompute() {
    for (storage, expect_recompute) in [
        (StorageConfig::PlatformShared, false),
        (StorageConfig::Recompute, true),
    ] {
        let spec = base_spec(PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 })
            .with_kv_retrieval(KvRetrievalSpec {
                count: 1,
                storage,
                scenario: KvScenario::Private,
                max_batch: 0,
                ports: 4,
            });
        let mut coord = spec.build().unwrap();
        let w = conv(30, 6.0)
            .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 3000 }));
        coord.inject(w.generate(0));
        coord.run();
        assert!(coord.all_serviced(), "{storage:?}");
        if expect_recompute {
            assert_eq!(coord.stats.recomputes, 30, "all misses recompute");
            for id in &coord.serviced {
                assert!(coord.pool[id].prompt_tokens > 3000);
                assert_eq!(coord.pool[id].past_tokens, 0);
            }
        } else {
            // 95% hit tier → most requests carry past context
            let hits = coord
                .serviced
                .iter()
                .filter(|id| coord.pool[*id].past_tokens == 3000)
                .count();
            assert!(hits >= 24, "hits={hits}");
        }
    }
}

#[test]
fn kv_hits_are_faster_than_recompute_end_to_end() {
    let run = |storage| {
        let spec = base_spec(PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 })
            .with_kv_retrieval(KvRetrievalSpec {
                count: 1,
                storage,
                scenario: KvScenario::Private,
                max_batch: 0,
                ports: 4,
            });
        let w = conv(40, 4.0)
            .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 24576 }));
        hermes::sim::driver::run(&spec, &w, &SloLadder::retrieval())
            .unwrap()
            .e2e
            .p50
    };
    let hit_tier = run(StorageConfig::PlatformShared);
    let recompute = run(StorageConfig::Recompute);
    // 24K tokens: retrieval (fast tier) must beat recomputation (paper §V-B)
    assert!(
        hit_tier < recompute,
        "24K: platform tier {hit_tier}s should beat recompute {recompute}s"
    );
}

#[test]
fn disaggregated_rag_combo_pipeline() {
    // RAG + disaggregated prefill/decode: three client kinds cooperating
    let rag = RagParams { docs: 6, doc_tokens: 500, ..Default::default() };
    let spec = base_spec(PoolSpec::Disaggregated { prefill: 2, decode: 1, local: false })
        .with_rag(RagSpec {
            count: 1,
            embed_model: hermes::hardware::models::E5_BASE,
            embed_npu: A100,
            retrieval_npu: GRACE_CPU,
            ivf: Default::default(),
            max_batch: 0,
        });
    let mut coord = spec.build().unwrap();
    coord.inject(conv(20, 4.0).with_pipeline(Pipeline::Rag(rag)).generate(0));
    coord.run();
    assert!(coord.all_serviced());
    // stages hop rag-client → prefill-client → decode-client
    assert!(coord.stats.transfers >= 40, "transfers={}", coord.stats.transfers);
    let m = RunMetrics::collect(&coord, &SloLadder::retrieval());
    assert_eq!(m.n_serviced, 20);
}

#[test]
fn reasoning_branches_respect_kv_limits() {
    let spec = base_spec(PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 });
    let w = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 12, 2.0)
        .with_reasoning(hermes::workload::trace::Reasoning::MultiPath {
            scale: 4.0,
            branches: 8,
        })
        .with_seed(23);
    let mut coord = spec.build().unwrap();
    coord.inject(w.generate(0));
    coord.run();
    assert!(coord.all_serviced());
    for id in &coord.serviced {
        let r = &coord.pool[id];
        assert_eq!(r.branches, 8);
        assert!(r.decode_complete());
        // KV peak accounted all branches
        assert!(r.kv_tokens_peak() >= 8.0 * r.output_tokens as f64);
    }
}

#[test]
fn bursty_arrivals_are_absorbed() {
    let spec = base_spec(PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 512 }, n: 2 });
    let w = conv(60, 6.0).with_arrival(hermes::util::rng::Arrival::Bursty {
        rate: 12.0,
        burst_mult: 5.0,
        calm_s: 5.0,
        burst_s: 1.0,
    });
    let m = hermes::sim::driver::run(&spec, &w, &SloLadder::standard()).unwrap();
    assert_eq!(m.n_serviced, 60);
    // bursts inflate tail latency beyond the median
    assert!(m.ttft.p99 > m.ttft.p50);
}
