//! Load-accounting invariants (the incremental-routing refactor's
//! acceptance tests):
//!
//! * differential: after *every* coordinator event in a mixed
//!   RAG / KV-retrieval / prefill / decode run, each client's O(1)
//!   incremental load equals a fresh full-pool recomputation;
//! * equivalence: routing from cached loads produces bit-identical
//!   simulations to the pre-refactor full-scan routing path;
//! * determinism: seeded runs reproduce identical metrics.
//!
//! The arena-vs-hashmap pool differential lives in
//! `rust/tests/pool_equivalence.rs`; `assert_load_invariant` now also
//! validates the pool's per-client resident index, so the differential
//! loop below checks that too.

use hermes::client::Client;
use hermes::config::slo::SloLadder;
use hermes::coordinator::{Coordinator, LoadMode};
use hermes::hardware::npu::H100;
use hermes::memory::storage::{KvScenario, StorageConfig};
use hermes::metrics::RunMetrics;
use hermes::sim::builder::{KvRetrievalSpec, PoolSpec, RagSpec, ServingSpec};
use hermes::workload::request::{KvParams, RagParams};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadMix, WorkloadSpec};

/// A serving system exercising every client kind: disaggregated
/// prefill/decode LLM clients (KV hand-off transfers), a RAG tier and a
/// KV-retrieval tier.
fn mixed_spec() -> ServingSpec {
    ServingSpec::new(
        "llama3-70b",
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_rag(RagSpec {
        count: 1,
        embed_model: hermes::hardware::models::E5_BASE,
        embed_npu: hermes::hardware::npu::A100,
        retrieval_npu: hermes::hardware::npu::GRACE_CPU,
        ivf: Default::default(),
        max_batch: 8,
    })
    .with_kv_retrieval(KvRetrievalSpec {
        count: 1,
        storage: StorageConfig::PlatformShared,
        scenario: KvScenario::Shared,
        max_batch: 8,
        ports: 4,
    })
    .with_seed(17)
}

/// Regular + RAG + KV-retrieval request classes, interleaved.
fn mixed_workload(n: usize) -> WorkloadMix {
    let base = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 0, 1.0).with_seed(23);
    let rag = base
        .clone()
        .with_pipeline(Pipeline::Rag(RagParams { docs: 4, doc_tokens: 256, ..Default::default() }));
    let kv = base
        .clone()
        .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 2048 }));
    WorkloadMix::new(vec![(0.5, base), (0.3, rag), (0.2, kv)]).scaled(n, 6.0)
}

#[test]
fn incremental_load_equals_recomputation_after_every_event() {
    let mut coord = mixed_spec().build().unwrap();
    coord.inject(mixed_workload(60).generate());
    let mut events = 0u64;
    while coord.step_event() {
        events += 1;
        // one source of truth for the comparison — the same check debug
        // builds run inside step_event, kept explicit here so the test
        // also guards release-mode test runs
        coord.assert_load_invariant();
    }
    assert!(coord.all_serviced(), "serviced {}", coord.serviced.len());
    assert!(events > 0);
    // drained system: every load counter returned to zero
    for c in &coord.clients {
        let l = c.load();
        assert_eq!(l.queued_requests, 0, "client {}", c.id());
        assert_eq!(l.tokens_left, 0.0, "client {}", c.id());
        assert_eq!(l.input_tokens, 0.0, "client {}", c.id());
    }
}

fn run_mode(mode: LoadMode) -> (Coordinator, RunMetrics) {
    let mut coord = mixed_spec().build().unwrap();
    coord.load_mode = mode;
    coord.inject(mixed_workload(80).generate());
    coord.run();
    let m = RunMetrics::collect(&coord, &SloLadder::retrieval());
    (coord, m)
}

#[test]
fn cached_loads_reproduce_full_scan_routing_exactly() {
    // the full-scan mode *is* the pre-refactor behavior; identical
    // routing decisions ⇒ identical event streams ⇒ identical metrics
    let (inc_coord, inc) = run_mode(LoadMode::Incremental);
    let (full_coord, full) = run_mode(LoadMode::FullScan);
    assert_eq!(inc_coord.serviced, full_coord.serviced, "completion order diverged");
    assert_eq!(inc_coord.clock, full_coord.clock);
    assert_eq!(inc.events, full.events);
    assert_eq!(inc.makespan, full.makespan);
    assert_eq!(inc.ttft_samples, full.ttft_samples);
    assert_eq!(inc.tpot_samples, full.tpot_samples);
    assert_eq!(inc.e2e_samples, full.e2e_samples);
    assert_eq!(inc.transfer_bytes, full.transfer_bytes);
}

#[test]
fn seeded_runs_are_deterministic() {
    let (_, a) = run_mode(LoadMode::Incremental);
    let (_, b) = run_mode(LoadMode::Incremental);
    assert_eq!(a.events, b.events);
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.ttft_samples, b.ttft_samples);
    assert_eq!(a.tpot_samples, b.tpot_samples);
    assert_eq!(a.e2e_samples, b.e2e_samples);
    assert_eq!(a.energy_joules, b.energy_joules);
    assert_eq!(a.goodput_frac, b.goodput_frac);
}
