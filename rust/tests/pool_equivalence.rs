//! Arena-pool acceptance tests (the arena-backed `RequestPool`
//! refactor's differential suite):
//!
//! * equivalence: a mixed RAG / KV-retrieval / prefill / decode run on
//!   the dense arena backend is bit-identical — serviced order, event
//!   count, clock, every latency sample — to the same run on the
//!   `HashMap` reference backend;
//! * residency: the per-client resident index (which
//!   `Client::recompute_load` now iterates instead of scanning the
//!   whole pool) matches every request's `client` field after every
//!   single event;
//! * counters: the pool operation counters the bench harness reports
//!   actually count.

use hermes::config::slo::SloLadder;
use hermes::coordinator::{Coordinator, LoadMode};
use hermes::hardware::npu::H100;
use hermes::memory::storage::{KvScenario, StorageConfig};
use hermes::metrics::RunMetrics;
use hermes::scheduler::{PoolBackend, RequestPool};
use hermes::sim::builder::{KvRetrievalSpec, PoolSpec, RagSpec, ServingSpec};
use hermes::workload::request::{KvParams, RagParams};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadMix, WorkloadSpec};

/// A serving system exercising every client kind: disaggregated
/// prefill/decode LLM clients (KV hand-off transfers), a RAG tier and a
/// KV-retrieval tier — the same shape as the load-invariant suite.
fn mixed_spec() -> ServingSpec {
    ServingSpec::new(
        "llama3-70b",
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_rag(RagSpec {
        count: 1,
        embed_model: hermes::hardware::models::E5_BASE,
        embed_npu: hermes::hardware::npu::A100,
        retrieval_npu: hermes::hardware::npu::GRACE_CPU,
        ivf: Default::default(),
        max_batch: 8,
    })
    .with_kv_retrieval(KvRetrievalSpec {
        count: 1,
        storage: StorageConfig::PlatformShared,
        scenario: KvScenario::Shared,
        max_batch: 8,
        ports: 4,
    })
    .with_seed(29)
}

/// Regular + RAG + KV-retrieval request classes, interleaved.
fn mixed_workload(n: usize) -> WorkloadMix {
    let base = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 0, 1.0).with_seed(31);
    let rag = base
        .clone()
        .with_pipeline(Pipeline::Rag(RagParams { docs: 4, doc_tokens: 256, ..Default::default() }));
    let kv = base
        .clone()
        .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 2048 }));
    WorkloadMix::new(vec![(0.5, base), (0.3, rag), (0.2, kv)]).scaled(n, 6.0)
}

fn run_backend(backend: PoolBackend) -> (Coordinator, RunMetrics) {
    let mut coord = mixed_spec().build().unwrap();
    coord.load_mode = LoadMode::Incremental;
    coord.pool = RequestPool::with_backend(backend);
    coord.inject(mixed_workload(80).generate());
    coord.run();
    let m = RunMetrics::collect(&coord, &SloLadder::retrieval());
    (coord, m)
}

#[test]
fn arena_pool_reproduces_map_pool_run_exactly() {
    let (arena_coord, arena) = run_backend(PoolBackend::Arena);
    let (map_coord, map) = run_backend(PoolBackend::Map);
    assert!(arena_coord.all_serviced(), "serviced {}", arena_coord.serviced.len());
    assert_eq!(
        arena_coord.serviced, map_coord.serviced,
        "completion order diverged between pool backends"
    );
    assert_eq!(arena_coord.clock, map_coord.clock);
    assert_eq!(arena.events, map.events);
    assert_eq!(arena.makespan, map.makespan);
    assert_eq!(arena.n_serviced, map.n_serviced);
    assert_eq!(arena.n_failed, map.n_failed);
    assert_eq!(arena.ttft_samples, map.ttft_samples);
    assert_eq!(arena.tpot_samples, map.tpot_samples);
    assert_eq!(arena.e2e_samples, map.e2e_samples);
    assert_eq!(arena.transfer_bytes, map.transfer_bytes);
    assert_eq!(arena.energy_joules, map.energy_joules);
    assert_eq!(arena.goodput_frac, map.goodput_frac);
}

#[test]
fn residency_index_matches_client_fields_after_every_event() {
    let mut coord = mixed_spec().build().unwrap();
    coord.inject(mixed_workload(60).generate());
    let mut events = 0u64;
    while coord.step_event() {
        events += 1;
        // validates both the resident index and the incremental loads;
        // explicit here so release-mode test runs are covered too
        coord.assert_load_invariant();
    }
    assert!(events > 0);
    assert!(coord.all_serviced(), "serviced {}", coord.serviced.len());
    // drained: nothing is resident on any client any more
    let ops = coord.pool.ops();
    assert_eq!(ops.resident, 0, "requests left resident after drain");
    assert!(ops.peak_resident > 0);
    assert_eq!(ops.len, 60);
}

#[test]
fn pool_op_counters_track_the_event_loop() {
    let mut coord = mixed_spec().build().unwrap();
    coord.inject(mixed_workload(20).generate());
    coord.pool.reset_ops();
    assert_eq!(coord.pool.ops().reads, 0);
    coord.run();
    let ops = coord.pool.ops();
    assert!(ops.reads > 0, "event loop must read the pool");
    assert!(ops.writes > 0, "event loop must write the pool");
    assert!(ops.slots >= ops.len);
}
