//! End-to-end integration over the config system, builder, coordinator
//! and metrics: every batching strategy, every router policy, config
//! round trips, Chrome-trace export, determinism.

use hermes::config::slo::SloLadder;
use hermes::config::SimConfig;
use hermes::coordinator::{LoadMetric, RoutePolicy};
use hermes::hardware::npu::H100;
use hermes::metrics::{trace_export, RunMetrics};
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use hermes::sim::driver;
use hermes::util::json::Json;
use hermes::workload::trace::{TraceKind, WorkloadSpec};

fn workload(n: usize, rate: f64, seed: u64) -> WorkloadSpec {
    WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, rate).with_seed(seed)
}

#[test]
fn every_batching_strategy_completes_the_workload() {
    let slo = SloLadder::standard();
    let pools = [
        PoolSpec::Combined { kind: BatchingKind::Static, n: 2 },
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
        PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 256 }, n: 2 },
        PoolSpec::Combined { kind: BatchingKind::Mixed, n: 2 },
        PoolSpec::Disaggregated { prefill: 1, decode: 1, local: false },
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: true },
    ];
    for pool in pools {
        let spec = ServingSpec::new("llama3-70b", H100, 4, pool).with_perf(PerfBackend::Poly);
        let m = driver::run(&spec, &workload(40, 4.0, 1), &slo).unwrap();
        assert_eq!(m.n_serviced, 40, "{}", spec.pool.label());
        assert_eq!(m.n_failed, 0);
        assert!(m.ttft.p50 > 0.0 && m.tpot.p50 > 0.0, "{}", spec.pool.label());
    }
}

#[test]
fn every_router_policy_works() {
    let slo = SloLadder::standard();
    let policies = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LoadBased(LoadMetric::InputLen),
        RoutePolicy::LoadBased(LoadMetric::OutputLen),
        RoutePolicy::LoadBased(LoadMetric::KvSize),
        RoutePolicy::LoadBased(LoadMetric::TokensLeft),
        RoutePolicy::HeavyLight {
            metric: LoadMetric::TokensLeft,
            threshold_tokens: 1024,
            heavy_frac: 0.5,
        },
    ];
    for policy in policies {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            4,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 4 },
        )
        .with_perf(PerfBackend::Poly)
        .with_route(policy);
        let m = driver::run(&spec, &workload(60, 8.0, 2), &slo).unwrap();
        assert_eq!(m.n_serviced, 60, "{policy:?}");
    }
}

#[test]
fn identical_seeds_identical_metrics() {
    let slo = SloLadder::standard();
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_perf(PerfBackend::Poly);
    let a = driver::run(&spec, &workload(50, 6.0, 7), &slo).unwrap();
    let b = driver::run(&spec, &workload(50, 6.0, 7), &slo).unwrap();
    assert_eq!(a.makespan, b.makespan);
    assert_eq!(a.events, b.events);
    assert_eq!(a.e2e_samples, b.e2e_samples);
    assert_eq!(a.energy_joules, b.energy_joules);
}

#[test]
fn config_json_end_to_end() {
    let doc = Json::parse(
        r#"{
        "model": "llama3-70b", "npu": "h100", "tp": 4,
        "pool": { "batching": "chunked", "n": 2, "chunk": 512 },
        "scheduler": { "max_batch_seqs": 64, "max_batch_tokens": 4096,
                       "packing": "least-work-left" },
        "router": "load:kv-size",
        "perf_model": "poly",
        "workload": { "trace": "azure-code", "n": 30, "rate": 3.0,
                      "arrival": "normal", "pipeline": "regular" },
        "seed": 3
    }"#,
    )
    .unwrap();
    let cfg = SimConfig::from_json(&doc).unwrap();
    let mut coord = cfg.serving.build().unwrap();
    coord.inject(cfg.workload.generate(0));
    coord.run();
    let m = RunMetrics::collect(&coord, &cfg.slo);
    assert_eq!(m.n_serviced, 30);

    // metrics JSON round-trips
    let j = Json::parse(&m.to_json().to_pretty()).unwrap();
    assert_eq!(j.usize_or("n_serviced", 0), 30);
}

#[test]
fn chrome_trace_is_valid_and_complete() {
    let slo = SloLadder::standard();
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Disaggregated { prefill: 1, decode: 1, local: false },
    )
    .with_perf(PerfBackend::Poly);
    let mut coord = spec.build().unwrap();
    coord.inject(workload(10, 4.0, 4).generate(0));
    coord.run();
    let _ = RunMetrics::collect(&coord, &slo);
    let doc = trace_export::chrome_trace(&coord);
    let text = doc.to_string();
    let parsed = Json::parse(&text).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // disaggregated pipeline: ≥2 stage spans + 1 marker per request
    assert!(events.len() >= 30, "events={}", events.len());
}

#[test]
fn multiple_models_served_concurrently() {
    // The paper's headline: "multiple heterogeneous clients servicing
    // distinct models simultaneously". Two pools serve two models; the
    // router must dispatch by request model.
    use hermes::client::{Client, LlmClient};
    use hermes::coordinator::{Coordinator, Router};
    use hermes::hardware::models::{LLAMA3_70B, MISTRAL_7B};
    use hermes::hardware::roofline::LlmCluster;
    use hermes::network::Network;
    use hermes::perfmodel::RooflinePerfModel;
    use hermes::scheduler::{LlmSched, Packing, SchedConfig};

    let mk = |id: usize, model: hermes::hardware::ModelSpec, tp: usize| -> Box<dyn Client> {
        let cluster = LlmCluster::new(model, H100, tp);
        Box::new(LlmClient::new(
            id,
            cluster.clone(),
            LlmSched::new(BatchingKind::Continuous, Packing::Fcfs, SchedConfig::default()),
            Box::new(RooflinePerfModel::new(cluster)),
        ))
    };
    let clients = vec![
        mk(0, LLAMA3_70B, 8),
        mk(1, LLAMA3_70B, 8),
        mk(2, MISTRAL_7B, 1),
    ];
    let mut coord = Coordinator::new(
        clients,
        Router::new(RoutePolicy::LoadBased(LoadMetric::TokensLeft)),
        Network::single_platform(3),
    );
    let mut reqs = workload(20, 5.0, 5).generate(0);
    reqs.extend(
        WorkloadSpec::new("mistral-7b", TraceKind::AzureConv, 20, 5.0)
            .with_seed(6)
            .generate(1000),
    );
    coord.inject(reqs);
    coord.run();
    assert!(coord.all_serviced());
    assert_eq!(coord.serviced.len(), 40);
    // the mistral client served only mistral requests
    assert!(coord.clients[2].stats().requests_served >= 20);
    for id in &coord.serviced {
        let r = &coord.pool[id];
        assert!(r.decode_complete());
    }
}

#[test]
fn higher_injection_rate_never_reduces_latency() {
    let slo = SloLadder::standard();
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 },
    )
    .with_perf(PerfBackend::Poly);
    let points =
        driver::sweep_rates(&spec, &workload(60, 1.0, 11), &slo, &[0.5, 4.0, 32.0]).unwrap();
    assert!(points[2].metrics.ttft.p99 >= points[0].metrics.ttft.p99 * 0.9);
    // throughput saturates rather than growing unboundedly
    assert!(points[2].metrics.throughput_tok_s < points[0].metrics.throughput_tok_s * 100.0);
}

#[test]
fn guarded_pipeline_passes_through_prepost_clients() {
    use hermes::sim::builder::PrePostSpec;
    let slo = SloLadder::standard();
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 1 },
    )
    .with_perf(PerfBackend::Poly)
    .with_prepost(PrePostSpec {
        count: 1,
        cores: 8,
        guard_npu: Some(hermes::hardware::npu::A100),
    });
    let w = workload(15, 3.0, 12).with_pipeline(hermes::workload::trace::Pipeline::Guarded);
    let m = driver::run(&spec, &w, &slo).unwrap();
    assert_eq!(m.n_serviced, 15);
    // four stages per request → at least 3 inter-stage hops recorded
    assert!(m.transfers >= 15);
}

#[test]
fn shipped_example_configs_parse_and_run() {
    for entry in std::fs::read_dir("examples/configs").unwrap() {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let mut cfg = SimConfig::from_file(path.to_str().unwrap())
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        // shrink the workload so the test stays fast, keep everything else
        cfg.workload.n_requests = cfg.workload.n_requests.min(30);
        // avoid PJRT setup cost in the test: poly is numerically identical
        if cfg.serving.perf == hermes::sim::builder::PerfBackend::PjrtMemo {
            cfg.serving.perf = hermes::sim::builder::PerfBackend::Poly;
        }
        let mut coord = cfg.serving.build().unwrap();
        coord.inject(cfg.workload.generate(0));
        coord.run();
        assert!(
            coord.all_serviced(),
            "{}: {} of {} serviced",
            path.display(),
            coord.serviced.len(),
            coord.pool.len()
        );
    }
}
