//! Concurrency guarantees of the thread-safe `ModelId` interning
//! registry (`model/mod.rs`, `OnceLock` + `RwLock`): parallel sweep
//! workers (`sim::parallel`) resolve, intern and register models
//! concurrently, so the registry must give every thread a consistent
//! view — same name → same id, ids valid for O(1) `spec()` indexing
//! forever after (the `Coordinator::transfer_bytes` hot path), and
//! alias lookups agreeing with serial interning.

use std::sync::Barrier;

use hermes::hardware::models::ModelSpec;
use hermes::model::{known_models, ModelId};

fn custom_spec(name: &'static str, params: f64) -> ModelSpec {
    ModelSpec {
        name,
        params,
        layers: 32,
        hidden: 4096,
        heads: 32,
        kv_heads: 8,
        d_head: 128,
        bytes_per_param: 2.0,
        decoder: true,
    }
}

#[test]
fn concurrent_interning_is_consistent_and_ids_stay_valid() {
    const THREADS: usize = 8;
    let shared = custom_spec("conc-shared-13b", 13e9);
    // one distinct spec per thread, leaked for the 'static name the
    // registry requires
    let per_thread: Vec<&'static str> = (0..THREADS)
        .map(|i| &*Box::leak(format!("conc-thread-{i}-7b").into_boxed_str()))
        .collect();

    let barrier = Barrier::new(THREADS);
    let outcomes: Vec<(ModelId, ModelId, ModelId, ModelId)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..THREADS)
            .map(|i| {
                let shared = shared.clone();
                let own_name = per_thread[i];
                let barrier = &barrier;
                scope.spawn(move || {
                    // maximize interleaving: all threads hit the
                    // registry at once
                    barrier.wait();
                    // same builtin through two aliases, racing readers
                    let builtin = ModelId::named("llama3-70b");
                    let alias = ModelId::named("Llama-3.1-70B");
                    // all threads race to register the SAME new name
                    // (register is idempotent for identical specs, and
                    // of_spec resolves-or-interns)
                    let shared_id = if i % 2 == 0 {
                        ModelId::register(shared.clone()).unwrap()
                    } else {
                        ModelId::of_spec(&shared)
                    };
                    // ... and each thread registers its own distinct one
                    let own = ModelId::of_spec(&custom_spec(own_name, 7e9));
                    // interleaved reads stay coherent mid-registration
                    assert_eq!(ModelId::resolve(own_name), Some(own));
                    assert_eq!(own.name(), own_name);
                    (builtin, alias, shared_id, own)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // every thread agrees on the builtin, its alias, and the raced name
    let (builtin0, _, shared0, _) = outcomes[0];
    for &(builtin, alias, shared_id, _) in &outcomes {
        assert_eq!(builtin, builtin0);
        assert_eq!(alias, builtin, "alias must intern to the canonical id");
        assert_eq!(shared_id, shared0, "raced registration split the id");
    }
    // distinct names got distinct ids
    let mut own_ids: Vec<ModelId> = outcomes.iter().map(|o| o.3).collect();
    own_ids.sort_unstable();
    own_ids.dedup();
    assert_eq!(own_ids.len(), THREADS, "distinct names must get distinct ids");

    // alias lookups agree with serial interning after the dust settles
    assert_eq!(ModelId::resolve("llama3-70b"), Some(builtin0));
    assert_eq!(ModelId::resolve("Llama-3.1-70B"), Some(builtin0));
    assert_eq!(ModelId::resolve("conc-shared-13b"), Some(shared0));
    assert_eq!(ModelId::resolve("Conc_Shared.13B"), Some(shared0), "normalization applies");

    // the O(1) spec() index (the transfer_bytes hot path) stays valid
    // for every id handed out during the race
    assert_eq!(shared0.spec().params, 13e9);
    assert!(shared0.spec().kv_bytes_per_token() > 0.0);
    for (i, &(_, _, _, own)) in outcomes.iter().enumerate() {
        assert_eq!(own.name(), per_thread[i]);
        assert_eq!(own.spec().params, 7e9);
        assert!(own.spec().kv_bytes_per_token() > 0.0);
    }
    // and the registry's name list contains everything exactly once
    let names = known_models();
    assert!(names.contains(&"conc-shared-13b"));
    assert_eq!(names.iter().filter(|&&n| n == "conc-shared-13b").count(), 1);
    for name in &per_thread {
        assert!(names.contains(name));
    }
}

#[test]
fn conflicting_redefinition_still_rejected_under_concurrency() {
    // the error path must hold under the write lock too: N threads
    // racing an identical registration all succeed with one id, then a
    // conflicting respec fails no matter which thread won the race
    let spec = custom_spec("conc-conflict-30b", 30e9);
    let ids: Vec<ModelId> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let spec = spec.clone();
                scope.spawn(move || ModelId::register(spec).unwrap())
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(ids.windows(2).all(|w| w[0] == w[1]));
    let conflict = ModelSpec { params: 31e9, ..spec };
    assert!(ModelId::register(conflict).is_err());
}
