//! Differential oracle for fault injection (docs/robustness.md):
//! every fault-plan query is a pure function of simulated time and
//! request identity, so a faulted run must be **bit-identical** across
//! the serial event loop, `--shards K` conservative-window domains and
//! `--jobs N` worker threads — and with faults absent, the recovery
//! machinery must be invisible (the fault-free differential suites stay
//! byte-exact).
//!
//! Covered:
//! * fault-free runs: zero fault counters, availability 1.0, and the
//!   `--faults off` contract (a cleared plan equals never having one);
//! * request deadlines without any fault plan: timeouts fire and
//!   account identically at every shard count;
//! * the full fault plan — a decode-client crash with orphan
//!   re-routing, a prefill slowdown window, link degradation and a
//!   short outage on the prefill rack's egress, transient hand-off
//!   failures with bounded backoff retries — bit-identical across
//!   shard counts, both `LoadMode`s, reruns and streamed arrivals;
//! * request conservation under crashes (serviced + failed ==
//!   injected; the per-event debug load invariant catches residency /
//!   KV leaks from the crash drain), with and without shedding;
//! * faulted sharded runs nested inside the `--jobs` sweep executor.

use hermes::config::slo::SloLadder;
use hermes::coordinator::shard::{run_sharded, Arrivals, ShardOutcome};
use hermes::coordinator::LoadMode;
use hermes::fault::{CrashSpec, FaultSpec, LinkFaultSpec, RetryPolicy, SlowdownSpec};
use hermes::hardware::npu::H100;
use hermes::memory::hierarchy::{TIER_DRAM, TIER_HBM};
use hermes::metrics::RunMetrics;
use hermes::network::Granularity;
use hermes::sim::builder::{MigrationSpec, NetSpec, PoolSpec, ServingSpec};
use hermes::sim::parallel;
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadMix, WorkloadSpec};

const MODEL: &str = "llama3-70b";

fn conv(n: usize, rate: f64) -> WorkloadSpec {
    WorkloadSpec::new(MODEL, TraceKind::AzureConv, n, rate)
        .with_pipeline(Pipeline::Disagg)
        .with_seed(29)
}

/// Cross-rack disaggregated pool (clients 0–1 prefill in rack 0,
/// clients 2–3 decode in rack 1 → two closure components → two
/// domains), the same shape shard_equivalence.rs pins fault-free.
fn disagg_spec() -> ServingSpec {
    ServingSpec::new(
        MODEL,
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_net(NetSpec::Hierarchy { per_platform: 1, per_rack: 2 })
    .with_migration(MigrationSpec {
        granularity: Some(Granularity::Layerwise { layers: 80 }),
        pool: vec![TIER_HBM, TIER_DRAM],
    })
    .with_seed(31)
}

/// Every fault kind at once, aimed so each one actually fires inside a
/// ~10-second run: crash one of the two decode clients mid-run (its
/// orphans re-route to the survivor), slow a prefill client, degrade
/// then briefly black out the prefill rack's egress (the prefill →
/// decode hand-off path), and give every hand-off a transient failure
/// probability absorbed by bounded backoff retries.
fn fault_spec() -> FaultSpec {
    let mut f = FaultSpec::new(101);
    f.crashes.push(CrashSpec { client: 3, at: 3.0, down_for: 4.0 });
    f.slowdowns.push(SlowdownSpec { client: 0, factor: 1.5, at: 1.0, dur: 6.0 });
    f.links.push(LinkFaultSpec { rack: 0, at: 2.0, dur: 2.0, degrade: Some(2.0) });
    f.links.push(LinkFaultSpec { rack: 0, at: 5.0, dur: 0.5, degrade: None });
    f.stage_failure_prob = 0.05;
    f.retry = RetryPolicy { max_attempts: 4, base: 0.05, factor: 2.0, jitter: 0.5 };
    f
}

fn outcome(
    spec: &ServingSpec,
    mix: &WorkloadMix,
    mode: LoadMode,
    stream: bool,
    shards: usize,
) -> ShardOutcome {
    let build = || {
        spec.build().map(|mut c| {
            c.load_mode = mode;
            c
        })
    };
    let arrivals = if stream {
        Arrivals::Stream(mix)
    } else {
        Arrivals::Inject(mix.generate())
    };
    run_sharded(build, arrivals, shards).unwrap()
}

/// Everything the differential needs in one string, now including the
/// failure-recovery counters. Peak counters stay out — beyond the
/// per-domain-max caveat shard_equivalence.rs documents, deadline event
/// copies are armed per stage accept in whichever domain accepts, so
/// domain-local queue peaks legitimately differ from the serial queue's
/// while every committed event, counter and timestamp still matches.
fn fingerprint(o: &ShardOutcome) -> String {
    let m = RunMetrics::collect_outcome(o, &SloLadder::standard());
    format!(
        "serviced={:?} failed={:?} clock={:?} events={} injected={} \
         transfers={} bytes={:?} secs={:?} recomputes={} stat_failed={} \
         retries={} timeouts={} shed={} orphaned={} energy={:?} \
         decisions={} metrics={:?}",
        o.serviced,
        o.failed,
        o.clock,
        o.stats.events,
        o.stats.injected,
        o.stats.transfers,
        o.stats.transfer_bytes,
        o.stats.transfer_seconds,
        o.stats.recomputes,
        o.stats.failed,
        o.stats.retries,
        o.stats.timeouts,
        o.stats.shed,
        o.stats.orphaned,
        o.energy_joules,
        o.decisions,
        m
    )
}

/// Both runs drained and conserved every request (`all_serviced` is
/// counter-based: serviced + failed == injected, so it holds for
/// faulted runs where some of those requests failed), and every record,
/// counter and derived metric matches bit-for-bit.
fn assert_bit_identical(serial: &ShardOutcome, sharded: &ShardOutcome, what: &str) {
    assert!(
        serial.all_serviced(),
        "{what}: serial run lost requests ({} serviced + {} failed of {})",
        serial.stats.serviced,
        serial.stats.failed,
        serial.stats.injected
    );
    assert!(
        sharded.all_serviced(),
        "{what}: sharded run lost requests ({} serviced + {} failed of {})",
        sharded.stats.serviced,
        sharded.stats.failed,
        sharded.stats.injected
    );
    assert_eq!(serial.records, sharded.records, "{what}: completion records diverged");
    assert_eq!(fingerprint(serial), fingerprint(sharded), "{what}");
}

#[test]
fn fault_free_runs_count_no_fault_metrics_and_match_a_cleared_plan() {
    for mode in [LoadMode::Incremental, LoadMode::FullScan] {
        let mix = WorkloadMix::single(conv(40, 6.0));
        let serial = outcome(&disagg_spec(), &mix, mode, false, 1);
        // the recovery machinery must be invisible without a plan
        assert_eq!(serial.stats.retries, 0);
        assert_eq!(serial.stats.timeouts, 0);
        assert_eq!(serial.stats.shed, 0);
        assert_eq!(serial.stats.orphaned, 0);
        let m = RunMetrics::collect_outcome(&serial, &SloLadder::standard());
        assert_eq!(m.availability, 1.0, "no fault plan means a fully-up fleet");
        for shards in [2, 4] {
            let sh = outcome(&disagg_spec(), &mix, mode, false, shards);
            assert_eq!(sh.domains, 2);
            assert_bit_identical(&serial, &sh, &format!("fault-free/{mode:?}/shards={shards}"));
        }
        // `--faults off` clears the plan before building — that must be
        // indistinguishable from a spec that never carried one
        let mut cleared = disagg_spec().with_faults(fault_spec());
        cleared.faults = None;
        let off = outcome(&cleared, &mix, mode, false, 1);
        assert_bit_identical(&serial, &off, &format!("fault-free/{mode:?}/--faults off"));
    }
}

#[test]
fn deadlines_fire_identically_at_every_shard_count_without_a_fault_plan() {
    // a deadline far below the achievable end-to-end latency: most
    // requests must time out, and the accounting must agree everywhere
    let mix = WorkloadMix::single(conv(30, 6.0).with_deadline(0.25));
    let serial = outcome(&disagg_spec(), &mix, LoadMode::Incremental, false, 1);
    assert!(serial.stats.timeouts > 0, "a 0.25s deadline must fire");
    assert_eq!(serial.stats.timeouts, serial.stats.failed, "timeouts are the only failures");
    assert_eq!(serial.stats.retries, 0, "deadlines are terminal, never retried");
    assert_eq!(serial.stats.orphaned, 0);
    for shards in [2, 4] {
        let sh = outcome(&disagg_spec(), &mix, LoadMode::Incremental, false, shards);
        assert_eq!(sh.domains, 2);
        assert_bit_identical(&serial, &sh, &format!("deadline/shards={shards}"));
    }
}

#[test]
fn faulted_run_is_bit_identical_across_shard_counts_load_modes_and_reruns() {
    let spec = disagg_spec().with_faults(fault_spec());
    let mix = WorkloadMix::single(conv(60, 8.0).with_deadline(8.0));
    for mode in [LoadMode::Incremental, LoadMode::FullScan] {
        let serial = outcome(&spec, &mix, mode, false, 1);
        // the plan visibly fired: the crash window always dents
        // availability, and at least one recovery path engaged
        let m = RunMetrics::collect_outcome(&serial, &SloLadder::standard());
        assert!(m.availability < 1.0, "crash window must dent availability");
        assert!(
            serial.stats.retries + serial.stats.timeouts + serial.stats.orphaned > 0,
            "the fault plan must visibly engage the recovery machinery"
        );
        assert!(serial.stats.shed <= serial.stats.failed);
        assert!(serial.stats.timeouts <= serial.stats.failed);
        // rerunning the identical spec reproduces the identical run
        let again = outcome(&spec, &mix, mode, false, 1);
        assert_bit_identical(&serial, &again, &format!("faulted/{mode:?}/rerun"));
        for shards in [2, 4] {
            let sh = outcome(&spec, &mix, mode, false, shards);
            assert_eq!(sh.domains, 2, "fault plans must not break the domain split");
            assert_bit_identical(&serial, &sh, &format!("faulted/{mode:?}/shards={shards}"));
        }
    }
    // streamed arrivals draw the same lazy PCG streams — same run
    let serial = outcome(&spec, &mix, LoadMode::Incremental, false, 1);
    let streamed = outcome(&spec, &mix, LoadMode::Incremental, true, 2);
    assert_bit_identical(&serial, &streamed, "faulted/stream/shards=2");
}

#[test]
fn lane_dark_crashes_conserve_requests_with_and_without_shedding() {
    // overlap crashes of BOTH decode clients so the decode role goes
    // fully dark over [2.5, 5.0): requests arriving at the hand-off
    // find no healthy candidate — with shedding they fail immediately,
    // without it they burn backoff retries against the dark lane. The
    // per-event debug load invariant (residency + KV accounting) runs
    // throughout, so a leaky crash drain fails this test by panicking.
    let mut dark = fault_spec();
    dark.crashes.clear();
    dark.crashes.push(CrashSpec { client: 2, at: 2.0, down_for: 3.0 });
    dark.crashes.push(CrashSpec { client: 3, at: 2.5, down_for: 2.5 });
    let mix = WorkloadMix::single(conv(60, 8.0).with_deadline(8.0));

    let mut shedding = dark.clone();
    shedding.shed = true;
    let shed_run = outcome(
        &disagg_spec().with_faults(shedding.clone()),
        &mix,
        LoadMode::Incremental,
        false,
        1,
    );
    assert!(shed_run.all_serviced(), "shedding must conserve requests");
    assert!(shed_run.stats.shed > 0, "a dark decode lane must shed");
    assert!(shed_run.stats.failed >= shed_run.stats.shed);

    let retry_run = outcome(
        &disagg_spec().with_faults(dark.clone()),
        &mix,
        LoadMode::Incremental,
        false,
        1,
    );
    assert!(retry_run.all_serviced(), "backoff retries must conserve requests");
    assert_eq!(retry_run.stats.shed, 0, "shedding is off");
    assert!(retry_run.stats.failed > 0, "bounded retries run out against a 2.5s-dark lane");
    assert!(retry_run.stats.retries > 0);

    // the dark-lane schedule shards bit-identically too
    for (label, plan) in [("shed", shedding), ("retry", dark)] {
        let spec = disagg_spec().with_faults(plan);
        let serial = outcome(&spec, &mix, LoadMode::Incremental, false, 1);
        let sh = outcome(&spec, &mix, LoadMode::Incremental, false, 2);
        assert_bit_identical(&serial, &sh, &format!("lane-dark/{label}/shards=2"));
    }
}

#[test]
fn faulted_sharded_runs_compose_with_the_parallel_sweep_executor() {
    // --shards inside --jobs with a live fault plan: per-decision PCG
    // streams are derived fresh from (seed, request, site, kind), so
    // concurrent workers share no RNG state to race on
    let spec = disagg_spec().with_faults(fault_spec());
    let mix = WorkloadMix::single(conv(40, 8.0).with_deadline(8.0));
    let serial = fingerprint(&outcome(&spec, &mix, LoadMode::Incremental, false, 1));
    let results = parallel::run(2, 2, |i| {
        let shards = [2, 4][i];
        let o = outcome(&spec, &mix, LoadMode::Incremental, false, shards);
        (shards, o.domains, fingerprint(&o))
    });
    for (shards, domains, fp) in results {
        assert_eq!(domains, 2, "shards={shards}");
        assert_eq!(fp, serial, "faulted run diverged under --jobs 2 (shards={shards})");
    }
}
