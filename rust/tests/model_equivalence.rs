//! Multi-model machinery acceptance (same style as
//! `pool_equivalence.rs`): the degenerate single-model path through the
//! new multi-model serving layer must be bit-identical to the plain
//! path it replaced.
//!
//! * identity routing: a `ModelRoute`-bearing pipeline under an
//!   identity policy (static 100% one model — or no policy at all)
//!   reproduces the plain pipeline's serviced order, clock and every
//!   latency/energy sample exactly;
//! * inert policy: configuring a model policy on a pipeline with no
//!   `ModelRoute` stages changes nothing;
//! * co-model dedup: listing the primary model as a co-model builds the
//!   same single-model clients;
//! * per-model loads: for single-model clients, `load_for_model` ==
//!   `load` after every event of a full mixed run (checked via the
//!   coordinator's extended load invariant).

use hermes::client::Client;
use hermes::config::slo::SloLadder;
use hermes::coordinator::Coordinator;
use hermes::hardware::npu::H100;
use hermes::metrics::RunMetrics;
use hermes::model::ModelId;
use hermes::model::policy::ModelPolicy;
use hermes::sim::builder::{PoolSpec, ServingSpec};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadSpec};

fn disagg_spec() -> ServingSpec {
    ServingSpec::new(
        "llama3-70b",
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_seed(37)
}

fn workload(n: usize, pipeline: Pipeline) -> Vec<hermes::workload::request::Request> {
    WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, 5.0)
        .with_seed(41)
        .with_pipeline(pipeline)
        .generate(0)
}

fn run(spec: &ServingSpec, pipeline: Pipeline) -> (Coordinator, RunMetrics) {
    let mut coord = spec.build().unwrap();
    coord.inject(workload(60, pipeline));
    coord.run();
    let m = RunMetrics::collect(&coord, &SloLadder::standard());
    (coord, m)
}

fn assert_bit_identical(a: &(Coordinator, RunMetrics), b: &(Coordinator, RunMetrics)) {
    let ((ca, ma), (cb, mb)) = (a, b);
    assert!(ca.all_serviced(), "serviced {}", ca.serviced.len());
    assert_eq!(ca.serviced, cb.serviced, "completion order diverged");
    assert_eq!(ca.clock, cb.clock);
    assert_eq!(ma.events, mb.events);
    assert_eq!(ma.makespan, mb.makespan);
    assert_eq!(ma.n_serviced, mb.n_serviced);
    assert_eq!(ma.n_failed, mb.n_failed);
    assert_eq!(ma.ttft_samples, mb.ttft_samples);
    assert_eq!(ma.tpot_samples, mb.tpot_samples);
    assert_eq!(ma.e2e_samples, mb.e2e_samples);
    assert_eq!(ma.transfer_bytes, mb.transfer_bytes);
    assert_eq!(ma.energy_joules, mb.energy_joules);
    assert_eq!(ma.goodput_frac, mb.goodput_frac);
}

#[test]
fn routed_pipeline_with_identity_policy_matches_plain_run() {
    let plain = run(&disagg_spec(), Pipeline::Regular);
    // same requests, but each one passes a ModelRoute stage resolved by
    // a static 100%-same-model policy before prefill
    let spec = disagg_spec().with_model_policy(ModelPolicy::Static {
        choices: vec![(ModelId::named("llama3-70b"), 1.0)],
    });
    let routed = run(&spec, Pipeline::Routed);
    assert_bit_identical(&plain, &routed);
}

#[test]
fn routed_pipeline_without_policy_matches_plain_run() {
    // no policy configured: ModelRoute is the identity stage
    let plain = run(&disagg_spec(), Pipeline::Regular);
    let routed = run(&disagg_spec(), Pipeline::Routed);
    assert_bit_identical(&plain, &routed);
}

#[test]
fn policy_on_plain_pipeline_is_inert() {
    let plain = run(&disagg_spec(), Pipeline::Regular);
    let with_policy = disagg_spec().with_model_policy(ModelPolicy::Threshold {
        threshold_tokens: 1024,
        small: ModelId::named("llama3-70b"),
        large: ModelId::named("llama3-70b"),
    });
    let run_b = run(&with_policy, Pipeline::Regular);
    assert_bit_identical(&plain, &run_b);
}

#[test]
fn primary_listed_as_co_model_dedupes_to_single_model_clients() {
    let plain = run(&disagg_spec(), Pipeline::Regular);
    let spec = disagg_spec().with_co_models(vec![ModelId::named("llama3-70b")]);
    {
        let coord = spec.build().unwrap();
        for c in &coord.clients {
            assert_eq!(
                c.served_models(),
                &[ModelId::named("llama3-70b")],
                "duplicate co-model must dedupe away"
            );
        }
    }
    let deduped = run(&spec, Pipeline::Regular);
    assert_bit_identical(&plain, &deduped);
}

/// Multi-model runs must be routing-identical across load modes too:
/// the per-model incremental counters and the per-model whole-pool
/// scan are two computations of the same candidate loads.
#[test]
fn multi_model_cascade_identical_across_load_modes() {
    use hermes::coordinator::LoadMode;

    let small = ModelId::named("llama3-8b");
    let large = ModelId::named("llama3-70b");
    let spec = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined {
            kind: hermes::scheduler::BatchingKind::Continuous,
            n: 2,
        },
    )
    .with_co_models(vec![small])
    .with_model_policy(ModelPolicy::Cascade { small, large, escalate: 0.35 })
    .with_seed(43);
    let run_mode = |mode: LoadMode| {
        let mut coord = spec.build().unwrap();
        coord.load_mode = mode;
        coord.inject(workload(50, Pipeline::Cascade));
        coord.run();
        let m = RunMetrics::collect(&coord, &SloLadder::standard());
        (coord, m)
    };
    let inc = run_mode(LoadMode::Incremental);
    let full = run_mode(LoadMode::FullScan);
    assert_bit_identical(&inc, &full);
    // and the run actually exercised both models
    let escalated = inc
        .0
        .serviced
        .iter()
        .filter(|id| inc.0.pool[*id].model == large)
        .count();
    assert!(escalated > 0 && escalated < inc.0.serviced.len());
}

/// Drive a run event-by-event, asserting the full load invariant —
/// including the per-(client, model) half — after every event, and
/// that single-model clients report identical aggregate and per-model
/// loads throughout.
#[test]
fn per_model_loads_match_aggregate_for_single_model_clients() {
    let m70 = ModelId::named("llama3-70b");
    let mut coord = disagg_spec().build().unwrap();
    coord.inject(workload(40, Pipeline::Regular));
    let mut events = 0u64;
    while coord.step_event() {
        events += 1;
        coord.assert_load_invariant();
        for c in &coord.clients {
            if c.served_models() == [m70] {
                assert_eq!(c.load_for_model(m70), c.load(), "event {events}");
            }
        }
    }
    assert!(events > 0);
    assert!(coord.all_serviced());
}
