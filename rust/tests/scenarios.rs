//! Scenario-registry integration: every shipped scenario file parses,
//! builds and generates; scenario documents round-trip through
//! serialize → load → build; and switching a scenario's `batching`
//! entry changes reported behavior with no Rust changes (the
//! data-driven acceptance criterion).

use hermes::scenario::{runner, Panel, Scenario};
use hermes::sim::builder::PoolSpec;
use hermes::util::json::Json;

#[test]
fn every_shipped_scenario_parses_builds_and_generates() {
    let names = Scenario::list();
    assert!(
        names.len() >= 12,
        "expected the full registry, got {names:?}"
    );
    for must in [
        "fig5", "fig6", "fig8", "fig9", "fig10", "fig11", "fig12", "fig13", "fig15",
        "table3_small", "table3_large", "ablations", "quickstart", "rag_heavy", "remote_kv",
        "heterogeneous",
    ] {
        assert!(names.iter().any(|n| n == must), "missing scenario {must}");
    }
    for name in names {
        let sc = Scenario::load(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(!sc.roster.is_empty(), "{name}: empty roster");
        let clients = sc.scale(true).clients;
        for entry in &sc.roster {
            let spec = sc
                .serving(entry, clients)
                .unwrap_or_else(|e| panic!("{name}: serving: {e:#}"));
            spec.build()
                .unwrap_or_else(|e| panic!("{name}: build: {e:#}"));
        }
        for panel in sc.panels_or_default() {
            let mix = sc
                .workload(Some(&panel), 16)
                .unwrap_or_else(|e| panic!("{name}/{}: workload: {e:#}", panel.label));
            assert_eq!(mix.n_total(), 16, "{name}/{}", panel.label);
            assert_eq!(mix.generate().len(), 16, "{name}/{}", panel.label);
            sc.slo(Some(&panel), &mix)
                .unwrap_or_else(|e| panic!("{name}/{}: slo: {e:#}", panel.label));
        }
    }
}

/// The `hermes scenario check` contract: every shipped file resolves
/// all model / model-policy / npu references at both scales.
#[test]
fn every_shipped_scenario_passes_reference_check() {
    let names = Scenario::list();
    for must in ["multi_model", "bench_multimodel_100k"] {
        assert!(names.iter().any(|n| n == must), "missing scenario {must}");
    }
    for name in names {
        let sc = Scenario::load(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        sc.check().unwrap_or_else(|e| panic!("{name}: check: {e:#}"));
    }
}

/// The multi-model scenario runs end-to-end: co-resident clients, a
/// cascade policy, and a two-route pipeline, with some requests
/// finishing on each model.
#[test]
fn multi_model_scenario_runs_end_to_end() {
    use hermes::model::ModelId;

    let sc = Scenario::load("multi_model").unwrap();
    let scale = sc.scale(true).clone();
    let spec = sc.serving(&sc.roster[0], scale.clients).unwrap();
    assert!(spec.co_models.contains(&ModelId::named("llama3-8b")));
    assert!(spec.model_policy.is_some());
    let mut coord = spec.build().unwrap();
    let n = scale.clients * scale.requests_per_client;
    coord.inject(sc.workload(None, n).unwrap().generate());
    coord.run();
    assert!(coord.all_serviced(), "serviced {}", coord.serviced.len());
    let large = ModelId::named("llama3-70b");
    let escalated = coord
        .serviced
        .iter()
        .filter(|id| coord.pool[*id].model == large)
        .count();
    assert!(
        escalated > 0 && escalated < coord.serviced.len(),
        "escalation fraction must split the population: {escalated}/{}",
        coord.serviced.len()
    );
}

#[test]
fn scenario_document_roundtrips_through_disk() {
    let sc = Scenario::load("fig10").unwrap();
    // serialize the parsed document and reload it from a fresh file
    let path = std::env::temp_dir().join("hermes_roundtrip_fig10.json");
    std::fs::write(&path, sc.doc.to_pretty()).unwrap();
    let re = Scenario::from_file(&path).unwrap();
    std::fs::remove_file(&path).ok();

    assert_eq!(re.name, sc.name);
    assert_eq!(re.roster, sc.roster);
    assert_eq!(re.panels.len(), sc.panels.len());
    assert_eq!(re.scale(true), sc.scale(true));
    assert_eq!(re.scale(false), sc.scale(false));
    // and the reloaded scenario still builds a runnable system
    let spec = re.serving(&re.roster[0], 2).unwrap();
    let mut coord = spec.build().unwrap();
    coord.inject(re.workload(None, 12).unwrap().generate());
    coord.run();
    assert!(coord.all_serviced());
}

/// The tentpole acceptance criterion: editing only the `batching` field
/// of a scenario file switches the policy (and the reported behavior)
/// without touching or recompiling experiment code.
#[test]
fn editing_batching_field_switches_policy_without_code_changes() {
    let template = |batching: &str| -> String {
        format!(
            r#"{{
                "model": "llama3-70b", "npu": "h100", "tp": 8,
                "batching": ["{batching}"],
                "perf_model": "roofline",
                "workload": {{ "trace": "azure-conv" }},
                "sweep": {{ "clients": 1, "requests_per_client": 25, "rates": [2.0] }},
                "seed": 11
            }}"#
        )
    };
    let run = |batching: &str| {
        let path = std::env::temp_dir().join(format!("hermes_swap_{batching}.json"));
        std::fs::write(&path, template(batching)).unwrap();
        let sc = Scenario::from_file(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let sweeps = runner::sweep(&sc, None, true).unwrap();
        assert_eq!(sweeps.len(), 1);
        (sweeps[0].label.clone(), sweeps[0].points[0].metrics.clone())
    };

    let (l_static, m_static) = run("static");
    let (l_cont, m_cont) = run("continuous");
    let (l_chunk, m_chunk) = run("chunked:256");
    assert_eq!(l_static, "static");
    assert_eq!(l_cont, "continuous");
    assert_eq!(l_chunk, "chunked");
    // same trace, same seed, same rates — only the policy differs, and
    // the reported latency/throughput moves
    assert_eq!(m_static.n_serviced, m_cont.n_serviced);
    let moved = (m_static.ttft.p50 - m_cont.ttft.p50).abs() > 1e-9
        || (m_static.throughput_tok_s - m_cont.throughput_tok_s).abs() > 1e-9;
    assert!(moved, "static vs continuous produced identical metrics");
    let moved_chunk = (m_chunk.ttft.p50 - m_cont.ttft.p50).abs() > 1e-9
        || (m_chunk.tpot.p50 - m_cont.tpot.p50).abs() > 1e-9;
    assert!(moved_chunk, "chunked vs continuous produced identical metrics");
}

#[test]
fn heterogeneous_roster_resolves_per_client_pool() {
    let sc = Scenario::load("heterogeneous").unwrap();
    let per_client = sc
        .roster
        .iter()
        .map(|e| e.pool(4))
        .find(|p| matches!(p, PoolSpec::PerClient { .. }))
        .expect("heterogeneous scenario must carry a per-client pool");
    assert_eq!(per_client.n_clients(), 4);
    let spec = sc.serving(&sc.roster[2], 4).unwrap();
    let mut coord = spec.build().unwrap();
    assert_eq!(coord.clients.len(), 4);
    coord.inject(sc.workload(None, 20).unwrap().generate());
    coord.run();
    assert!(coord.all_serviced());
}

/// Table III methodology: auxiliary tiers exist only for the panels
/// whose pipeline uses them, so idle RAG/KV clients never skew the
/// throughput/energy winner columns of regular/reasoning panels.
#[test]
fn table3_provisions_aux_tiers_per_panel() {
    let sc = Scenario::load("table3_small").unwrap();
    let panels = sc.panels_or_default();
    let by_label = |l: &str| panels.iter().find(|p| p.label == l).unwrap();
    let spec = |p: &Panel| sc.serving_panel(&sc.roster[0], 4, Some(p)).unwrap();

    let regular = spec(by_label("code/regular"));
    assert!(regular.rag.is_none() && regular.kv_retrieval.is_none());
    let rag = spec(by_label("code/rag"));
    assert!(rag.rag.is_some() && rag.kv_retrieval.is_none());
    let kv = spec(by_label("conv/memory-cache"));
    assert!(kv.kv_retrieval.is_some() && kv.rag.is_none());
}

#[test]
fn malformed_rate_ladders_error_instead_of_sweeping_nothing() {
    for bad in [
        r#"{"batching": ["continuous"], "workload": {},
            "sweep": {"rates": ["1.0", "2.0"]}}"#,
        r#"{"batching": ["continuous"], "workload": {},
            "sweep": {"full": {"rates": []}}}"#,
    ] {
        assert!(
            Scenario::from_json("bad", Json::parse(bad).unwrap()).is_err(),
            "{bad}"
        );
    }
}

#[test]
fn workload_mix_scenario_runs_end_to_end() {
    let sc = Scenario::load("rag_heavy").unwrap();
    let mix = sc.workload(None, 24).unwrap();
    assert_eq!(mix.classes.len(), 2, "rag_heavy is a two-class mix");
    let spec = sc.serving(&sc.roster[0], 2).unwrap();
    assert!(spec.rag.is_some(), "rag tier provisioned from the file");
    let mut coord = spec.build().unwrap();
    coord.inject(mix.generate());
    coord.run();
    assert!(coord.all_serviced());
    // auto SLO resolves to the retrieval ladder (RAG-dominated mix)
    assert_eq!(sc.slo(None, &mix).unwrap().ttft_base, 1.0);
}
