//! Per-instance pool-op accounting (`scheduler/pool.rs`): the
//! read/write counters behind `hermes bench`'s `pool_*` columns are
//! fields of each `RequestPool`, not process globals — so two
//! coordinators running interleaved on one thread, or concurrently on
//! the `--jobs` worker pool, each report exactly the counts they would
//! report running alone. Regression guard for the accounting the
//! parallel executor depends on: a shared counter would double-count
//! under fan-out and silently corrupt the bench columns.

use hermes::coordinator::Coordinator;
use hermes::hardware::npu::H100;
use hermes::scheduler::{BatchingKind, PoolOps};
use hermes::sim::builder::{PoolSpec, ServingSpec};
use hermes::sim::parallel;
use hermes::workload::trace::{TraceKind, WorkloadSpec};

/// Two deliberately different runs so their counter totals differ —
/// equal totals must come from isolation, not coincidence.
fn configs() -> [(ServingSpec, WorkloadSpec); 2] {
    let spec_a = ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
    );
    let w_a = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 30, 2.0).with_seed(11);
    let spec_b = ServingSpec::new(
        "llama3-8b",
        H100,
        1,
        PoolSpec::Combined { kind: BatchingKind::Chunked { chunk: 512 }, n: 3 },
    );
    let w_b = WorkloadSpec::new("llama3-8b", TraceKind::AzureCode, 45, 3.0).with_seed(23);
    [(spec_a, w_a), (spec_b, w_b)]
}

/// Mirror the bench harness's measurement window: counters reset after
/// injection, read after the run.
fn prepared(spec: &ServingSpec, w: &WorkloadSpec) -> Coordinator {
    let mut coord = spec.build().unwrap();
    coord.inject(w.generate(0));
    coord.pool.reset_ops();
    coord
}

fn run_alone(spec: &ServingSpec, w: &WorkloadSpec) -> PoolOps {
    let mut coord = prepared(spec, w);
    coord.run();
    coord.pool.ops()
}

#[test]
fn interleaved_coordinators_count_pool_ops_as_if_alone() {
    let [(spec_a, w_a), (spec_b, w_b)] = configs();
    let alone_a = run_alone(&spec_a, &w_a);
    let alone_b = run_alone(&spec_b, &w_b);
    assert!(alone_a.reads > 0 && alone_a.writes > 0);
    assert_ne!(
        (alone_a.reads, alone_a.writes),
        (alone_b.reads, alone_b.writes),
        "runs must differ for the isolation check to mean anything"
    );

    // drive both simulations event-by-event on ONE thread, strictly
    // alternating — shared/global counters would blend the tallies
    let mut ca = prepared(&spec_a, &w_a);
    let mut cb = prepared(&spec_b, &w_b);
    let (mut more_a, mut more_b) = (true, true);
    while more_a || more_b {
        if more_a {
            more_a = ca.step_event();
        }
        if more_b {
            more_b = cb.step_event();
        }
    }
    assert_eq!(ca.pool.ops(), alone_a, "interleaving changed A's pool accounting");
    assert_eq!(cb.pool.ops(), alone_b, "interleaving changed B's pool accounting");
}

#[test]
fn parallel_coordinators_count_pool_ops_as_if_alone() {
    let [(spec_a, w_a), (spec_b, w_b)] = configs();
    let alone = [run_alone(&spec_a, &w_a), run_alone(&spec_b, &w_b)];
    // both runs concurrently on the worker pool, twice over, so the two
    // pools' Cell counters tick at the same time on different threads
    let pairs: [(&ServingSpec, &WorkloadSpec); 4] =
        [(&spec_a, &w_a), (&spec_b, &w_b), (&spec_a, &w_a), (&spec_b, &w_b)];
    let ops = parallel::run(4, 4, |i| {
        let (spec, w) = pairs[i];
        run_alone(spec, w)
    });
    for (i, got) in ops.into_iter().enumerate() {
        assert_eq!(got, alone[i % 2], "concurrent run {i} diverged from its solo accounting");
    }
}
