//! Differential oracle for sharded execution (`--shards K`,
//! docs/performance.md "Sharded execution"): `run_sharded` must be
//! bit-identical to the serial event loop at every shard count, because
//! shards = 1 *is* the serial loop and a multi-domain run only differs
//! in mechanism — conservative time windows of width = the DCN one-way
//! latency, with cross-domain work exchanged at window barriers in
//! deterministic `(instant, source domain, emission seq)` order.
//!
//! Covered:
//! * cross-rack disaggregated serving (prefill and decode racks in
//!   separate domains) under the Regular and Disagg pipelines, the
//!   latter with layerwise KV-migration pricing — migration count,
//!   bytes and exposed seconds must match the serial run exactly;
//! * a mixed regular / RAG / KV-retrieval workload whose aux tiers
//!   shard into their own domains, with injected and streaming
//!   arrivals;
//! * the multi-model cascade: a configured model policy routes requests
//!   dynamically, so the planner documents its serial fallback
//!   (`domains == 1`) and the outcome is still bit-identical;
//! * both `LoadMode`s, and composition with the `--jobs` sweep
//!   executor (domain threads nested inside worker threads).

use hermes::config::slo::SloLadder;
use hermes::coordinator::shard::{run_sharded, Arrivals, ShardOutcome};
use hermes::coordinator::LoadMode;
use hermes::hardware::models::E5_BASE;
use hermes::hardware::npu::{GRACE_CPU, H100};
use hermes::memory::hierarchy::{TIER_DRAM, TIER_HBM};
use hermes::memory::storage::{KvScenario, StorageConfig};
use hermes::metrics::RunMetrics;
use hermes::model::policy::ModelPolicy;
use hermes::model::ModelId;
use hermes::network::Granularity;
use hermes::scheduler::BatchingKind;
use hermes::sim::builder::{
    KvRetrievalSpec, MigrationSpec, NetSpec, PoolSpec, RagSpec, ServingSpec,
};
use hermes::sim::parallel;
use hermes::workload::request::{KvParams, RagParams};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadMix, WorkloadSpec};

const MODEL: &str = "llama3-70b";

fn conv(n: usize, rate: f64) -> WorkloadSpec {
    WorkloadSpec::new(MODEL, TraceKind::AzureConv, n, rate).with_seed(29)
}

/// Cross-rack disaggregated pool: both prefill clients in rack 0, both
/// decode clients in rack 1 → two closure components → two domains
/// (also at shards = 4: effective domains = min(shards, components)).
fn disagg_spec() -> ServingSpec {
    ServingSpec::new(
        MODEL,
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_net(NetSpec::Hierarchy { per_platform: 1, per_rack: 2 })
    .with_migration(MigrationSpec {
        granularity: Some(Granularity::Layerwise { layers: 80 }),
        pool: vec![TIER_HBM, TIER_DRAM],
    })
    .with_seed(31)
}

/// One client per rack: the two LLM racks union through the shared
/// prefill/decode candidate sets, the RAG and KV tiers stay disjoint →
/// three components (2 domains at shards = 2, 3 at shards = 4).
fn mixed_spec() -> ServingSpec {
    ServingSpec::new(
        MODEL,
        H100,
        4,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
    )
    .with_net(NetSpec::Hierarchy { per_platform: 1, per_rack: 1 })
    .with_rag(RagSpec {
        count: 1,
        embed_model: E5_BASE,
        embed_npu: GRACE_CPU,
        retrieval_npu: GRACE_CPU,
        ivf: Default::default(),
        max_batch: 0,
    })
    .with_kv_retrieval(KvRetrievalSpec {
        count: 1,
        storage: StorageConfig::PlatformShared,
        scenario: KvScenario::Shared,
        max_batch: 0,
        ports: 4,
    })
    .with_seed(37)
}

fn mixed_mix(n: usize) -> WorkloadMix {
    WorkloadMix::new(vec![
        (0.4, conv(n, 6.0)),
        (
            0.3,
            conv(n, 6.0).with_pipeline(Pipeline::Rag(RagParams {
                docs: 4,
                doc_tokens: 400,
                ..Default::default()
            })),
        ),
        (
            0.3,
            conv(n, 6.0)
                .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 2000 })),
        ),
    ])
}

fn outcome(
    spec: &ServingSpec,
    mix: &WorkloadMix,
    mode: LoadMode,
    stream: bool,
    shards: usize,
) -> ShardOutcome {
    let build = || {
        spec.build().map(|mut c| {
            c.load_mode = mode;
            c
        })
    };
    let arrivals = if stream {
        Arrivals::Stream(mix)
    } else {
        Arrivals::Inject(mix.generate())
    };
    run_sharded(build, arrivals, shards).unwrap()
}

/// Everything the differential needs in one string: serviced order,
/// final clock, counters and every derived latency / energy / transfer
/// sample. Peak counters stay out — `peak_queue` is a per-domain max
/// and the in-flight / pool peaks are sums of per-domain peaks, so they
/// bound the serial values rather than equal them (documented in
/// docs/performance.md).
fn fingerprint(o: &ShardOutcome) -> String {
    let m = RunMetrics::collect_outcome(o, &SloLadder::standard());
    format!(
        "serviced={:?} failed={:?} clock={:?} events={} injected={} \
         transfers={} bytes={:?} secs={:?} recomputes={} stat_failed={} \
         energy={:?} decisions={} metrics={:?}",
        o.serviced,
        o.failed,
        o.clock,
        o.stats.events,
        o.stats.injected,
        o.stats.transfers,
        o.stats.transfer_bytes,
        o.stats.transfer_seconds,
        o.stats.recomputes,
        o.stats.failed,
        o.energy_joules,
        o.decisions,
        m
    )
}

fn assert_bit_identical(serial: &ShardOutcome, sharded: &ShardOutcome, what: &str) {
    assert!(
        serial.all_serviced(),
        "{what}: serial run left requests unfinished ({} of {})",
        serial.serviced.len(),
        serial.stats.injected
    );
    assert!(
        sharded.all_serviced(),
        "{what}: sharded run left requests unfinished ({} of {})",
        sharded.serviced.len(),
        sharded.stats.injected
    );
    // per-request completion records carry every timestamp and token
    // count — arrival, TTFT, last token, decode counts — so equality
    // here pins each individual sample, not just the aggregates
    assert_eq!(serial.records, sharded.records, "{what}: completion records diverged");
    assert_eq!(fingerprint(serial), fingerprint(sharded), "{what}");
}

#[test]
fn cross_rack_disagg_is_bit_identical_across_shard_counts_and_load_modes() {
    for mode in [LoadMode::Incremental, LoadMode::FullScan] {
        for pipeline in [Pipeline::Regular, Pipeline::Disagg] {
            let mix = WorkloadMix::single(conv(40, 6.0).with_pipeline(pipeline));
            let serial = outcome(&disagg_spec(), &mix, mode, false, 1);
            assert_eq!(serial.domains, 1, "shards=1 must take the serial path");
            for shards in [2, 4] {
                let sh = outcome(&disagg_spec(), &mix, mode, false, shards);
                assert_eq!(sh.shards, shards);
                assert_eq!(
                    sh.domains, 2,
                    "prefill rack + decode rack = two components, so two \
                     domains even when four shards are requested"
                );
                assert_bit_identical(
                    &serial,
                    &sh,
                    &format!("{pipeline:?}/{mode:?}/shards={shards}"),
                );
            }
        }
    }
}

#[test]
fn cross_domain_kv_migrations_price_identically_at_every_shard_count() {
    // every Disagg request hands its KV across the prefill→decode rack
    // boundary — under sharding that is a cross-domain hop priced by
    // the orchestrator at the window barrier, and the layerwise
    // slicing + tiered staging must come out byte- and second-exact
    let n = 40;
    let mix = WorkloadMix::single(conv(n, 6.0).with_pipeline(Pipeline::Disagg));
    let serial = outcome(&disagg_spec(), &mix, LoadMode::Incremental, false, 1);
    assert_eq!(serial.stats.transfers, n as u64, "one explicit migration per request");
    assert!(serial.stats.transfer_bytes > 0.0);
    assert!(serial.stats.transfer_seconds > 0.0, "staged layerwise hand-off takes time");
    for shards in [2, 4] {
        let sh = outcome(&disagg_spec(), &mix, LoadMode::Incremental, false, shards);
        assert_eq!(sh.domains, 2);
        assert_eq!(sh.stats.transfers, serial.stats.transfers);
        assert_eq!(sh.stats.transfer_bytes, serial.stats.transfer_bytes);
        assert_eq!(sh.stats.transfer_seconds, serial.stats.transfer_seconds);
        assert_bit_identical(&serial, &sh, &format!("migration/shards={shards}"));
    }
}

#[test]
fn mixed_rag_kv_workload_shards_bit_identically_injected_and_streaming() {
    let mix = mixed_mix(60);
    let serial = outcome(&mixed_spec(), &mix, LoadMode::Incremental, false, 1);
    assert_eq!(serial.domains, 1);
    // streaming arrivals draw the same PCG streams lazily — the
    // serial-vs-serial equivalence is pinned elsewhere
    // (retirement_equivalence); here it anchors the streamed sharded
    // runs below to the same fingerprint
    let serial_stream = outcome(&mixed_spec(), &mix, LoadMode::Incremental, true, 1);
    assert_bit_identical(&serial, &serial_stream, "stream/serial");
    for (shards, want_domains) in [(2, 2), (4, 3)] {
        let inj = outcome(&mixed_spec(), &mix, LoadMode::Incremental, false, shards);
        assert_eq!(
            inj.domains, want_domains,
            "LLM racks union through shared prefill/decode candidates; \
             RAG and KV tiers are their own components"
        );
        assert_bit_identical(&serial, &inj, &format!("mixed/inject/shards={shards}"));
        let st = outcome(&mixed_spec(), &mix, LoadMode::Incremental, true, shards);
        assert_eq!(st.domains, want_domains);
        assert_bit_identical(&serial, &st, &format!("mixed/stream/shards={shards}"));
    }
}

#[test]
fn multi_model_cascade_falls_back_to_serial_and_stays_bit_identical() {
    // a model policy rewrites request models at ModelRoute stages, so
    // the closure over (stage kind, model) cannot pin candidates per
    // domain upfront — the planner refuses and runs the serial loop
    // (documented fallback, docs/performance.md "Sharded execution")
    let small = ModelId::named("llama3-8b");
    let large = ModelId::named(MODEL);
    let spec = ServingSpec::new(
        MODEL,
        H100,
        4,
        PoolSpec::Combined { kind: BatchingKind::Continuous, n: 2 },
    )
    .with_net(NetSpec::Hierarchy { per_platform: 1, per_rack: 1 })
    .with_co_models(vec![small])
    .with_model_policy(ModelPolicy::Cascade { small, large, escalate: 0.35 })
    .with_seed(43);
    let mix = WorkloadMix::single(conv(30, 4.0).with_pipeline(Pipeline::Cascade));
    let serial = outcome(&spec, &mix, LoadMode::Incremental, false, 1);
    for shards in [2, 4] {
        let sh = outcome(&spec, &mix, LoadMode::Incremental, false, shards);
        assert_eq!(sh.shards, shards, "the requested count is still reported");
        assert_eq!(sh.domains, 1, "model-policy runs must fall back to serial");
        assert_bit_identical(&serial, &sh, &format!("cascade/shards={shards}"));
    }
}

#[test]
fn sharded_runs_compose_with_the_parallel_sweep_executor() {
    // --shards inside --jobs: domain threads nested in worker threads.
    // Two concurrent sharded runs (at different shard counts) must both
    // reproduce the serial fingerprint computed up front.
    let spec = disagg_spec();
    let mix = WorkloadMix::single(conv(30, 6.0).with_pipeline(Pipeline::Disagg));
    let serial = fingerprint(&outcome(&spec, &mix, LoadMode::Incremental, false, 1));
    let results = parallel::run(2, 2, |i| {
        let shards = [2, 4][i];
        let o = outcome(&spec, &mix, LoadMode::Incremental, false, shards);
        (shards, o.domains, fingerprint(&o))
    });
    for (shards, domains, fp) in results {
        assert_eq!(domains, 2, "shards={shards}");
        assert_eq!(fp, serial, "sharded run diverged under --jobs 2 (shards={shards})");
    }
}
