//! O(in-flight) memory acceptance (the streaming-arrivals + request-
//! retirement refactor's differential suite, same style as
//! `pool_equivalence.rs`):
//!
//! * equivalence: a run fed by the lazy arrival source with retirement
//!   on — the O(peak in-flight) configuration — is bit-identical to
//!   the materialized/retained baseline (serviced order, clock, event
//!   count, every latency/energy sample) on plain-LLM, mixed
//!   RAG/KV-retrieval, and multi-model cascade scenarios, in both
//!   `LoadMode`s;
//! * metrics: record-based `RunMetrics::collect` reproduces the legacy
//!   retained-pool scan (`collect_from_pool`) bit for bit;
//! * memory: under streaming + retirement the pool's live high-water
//!   mark equals `CoordStats::peak_inflight` and stays far below the
//!   trace length, and every slot is freed by the end;
//! * determinism: freelist slot reuse is deterministic — two identical
//!   runs produce identical serviced order AND identical per-event
//!   slot assignments.

use hermes::config::slo::SloLadder;
use hermes::coordinator::{Coordinator, LoadMode};
use hermes::hardware::npu::H100;
use hermes::memory::storage::{KvScenario, StorageConfig};
use hermes::metrics::RunMetrics;
use hermes::model::policy::ModelPolicy;
use hermes::model::ModelId;
use hermes::scheduler::{PoolBackend, RequestPool};
use hermes::sim::builder::{KvRetrievalSpec, PoolSpec, RagSpec, ServingSpec};
use hermes::util::rng::Arrival;
use hermes::workload::request::{KvParams, RagParams};
use hermes::workload::trace::{Pipeline, TraceKind, WorkloadMix, WorkloadSpec};

/// One run configuration along the two new axes.
#[derive(Clone, Copy)]
struct Exec {
    stream: bool,
    retire: bool,
    mode: LoadMode,
    backend: PoolBackend,
}

const RETAINED: Exec = Exec {
    stream: false,
    retire: false,
    mode: LoadMode::Incremental,
    backend: PoolBackend::Arena,
};

const STREAMED: Exec = Exec {
    stream: true,
    retire: true,
    mode: LoadMode::Incremental,
    backend: PoolBackend::Arena,
};

fn run(spec: &ServingSpec, mix: &WorkloadMix, exec: Exec) -> (Coordinator, RunMetrics) {
    let mut coord = spec.build().unwrap();
    coord.load_mode = exec.mode;
    coord.pool = RequestPool::with_backend(exec.backend);
    coord.retire = exec.retire;
    if exec.stream {
        coord.stream(mix);
    } else {
        coord.inject(mix.generate());
    }
    coord.run();
    let m = RunMetrics::collect(&coord, &SloLadder::retrieval());
    (coord, m)
}

fn assert_bit_identical(a: &(Coordinator, RunMetrics), b: &(Coordinator, RunMetrics)) {
    let ((ca, ma), (cb, mb)) = (a, b);
    assert!(ca.all_serviced(), "serviced {}", ca.serviced.len());
    assert!(cb.all_serviced(), "serviced {}", cb.serviced.len());
    assert_eq!(ca.serviced, cb.serviced, "completion order diverged");
    assert_eq!(ca.failed, cb.failed, "failure set diverged");
    assert_eq!(ca.clock, cb.clock);
    assert_eq!(ma.events, mb.events);
    assert_eq!(ma.n_requests, mb.n_requests);
    assert_eq!(ma.makespan, mb.makespan);
    assert_eq!(ma.n_serviced, mb.n_serviced);
    assert_eq!(ma.n_failed, mb.n_failed);
    assert_eq!(ma.ttft_samples, mb.ttft_samples);
    assert_eq!(ma.tpot_samples, mb.tpot_samples);
    assert_eq!(ma.e2e_samples, mb.e2e_samples);
    assert_eq!(ma.transfer_bytes, mb.transfer_bytes);
    assert_eq!(ma.energy_joules, mb.energy_joules);
    assert_eq!(ma.goodput_frac, mb.goodput_frac);
    assert_eq!(ma.throughput_tok_s, mb.throughput_tok_s);
}

// ---- scenario shapes -------------------------------------------------------

fn llm_spec() -> ServingSpec {
    ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined {
            kind: hermes::scheduler::BatchingKind::Continuous,
            n: 2,
        },
    )
    .with_seed(47)
}

fn llm_mix(n: usize) -> WorkloadMix {
    WorkloadMix::single(
        WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, n, 4.0).with_seed(53),
    )
}

/// Disaggregated LLM + RAG tier + KV-retrieval tier (every client kind,
/// every transfer path) — the same shape as the load-invariant suite.
fn mixed_spec() -> ServingSpec {
    ServingSpec::new(
        "llama3-70b",
        H100,
        4,
        PoolSpec::Disaggregated { prefill: 2, decode: 2, local: false },
    )
    .with_rag(RagSpec {
        count: 1,
        embed_model: hermes::hardware::models::E5_BASE,
        embed_npu: hermes::hardware::npu::A100,
        retrieval_npu: hermes::hardware::npu::GRACE_CPU,
        ivf: Default::default(),
        max_batch: 8,
    })
    .with_kv_retrieval(KvRetrievalSpec {
        count: 1,
        storage: StorageConfig::PlatformShared,
        scenario: KvScenario::Shared,
        max_batch: 8,
        ports: 4,
    })
    .with_seed(59)
}

fn mixed_mix(n: usize) -> WorkloadMix {
    let base = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 0, 1.0).with_seed(61);
    let rag = base.clone().with_pipeline(Pipeline::Rag(RagParams {
        docs: 4,
        doc_tokens: 256,
        ..Default::default()
    }));
    let kv = base
        .clone()
        .with_pipeline(Pipeline::KvRetrieval(KvParams { cached_tokens: 2048 }));
    WorkloadMix::new(vec![(0.5, base), (0.3, rag), (0.2, kv)]).scaled(n, 6.0)
}

fn multimodel_spec() -> ServingSpec {
    let small = ModelId::named("llama3-8b");
    let large = ModelId::named("llama3-70b");
    ServingSpec::new(
        "llama3-70b",
        H100,
        8,
        PoolSpec::Combined {
            kind: hermes::scheduler::BatchingKind::Continuous,
            n: 2,
        },
    )
    .with_co_models(vec![small])
    .with_model_policy(ModelPolicy::Cascade { small, large, escalate: 0.35 })
    .with_seed(67)
}

fn multimodel_mix(n: usize) -> WorkloadMix {
    WorkloadMix::single(
        WorkloadSpec::new("llama3-8b", TraceKind::AzureConv, n, 5.0)
            .with_seed(71)
            .with_pipeline(Pipeline::Cascade),
    )
}

// ---- equivalence -----------------------------------------------------------

#[test]
fn llm_streaming_retirement_matches_materialized_both_load_modes() {
    let mix = llm_mix(60);
    for mode in [LoadMode::Incremental, LoadMode::FullScan] {
        let retained = run(&llm_spec(), &mix, Exec { mode, ..RETAINED });
        let streamed = run(&llm_spec(), &mix, Exec { mode, ..STREAMED });
        assert_bit_identical(&retained, &streamed);
    }
}

#[test]
fn mixed_pipelines_identical_across_all_four_exec_combinations() {
    let mix = mixed_mix(80);
    let baseline = run(&mixed_spec(), &mix, RETAINED);
    for stream in [false, true] {
        for retire in [false, true] {
            let other = run(&mixed_spec(), &mix, Exec { stream, retire, ..RETAINED });
            assert_bit_identical(&baseline, &other);
        }
    }
    // and the map backend retires identically (freelist is arena-only,
    // but the API contract is shared)
    let map = run(&mixed_spec(), &mix, Exec { backend: PoolBackend::Map, ..STREAMED });
    assert_bit_identical(&baseline, &map);
}

#[test]
fn multimodel_cascade_streaming_retirement_matches_materialized() {
    let mix = multimodel_mix(50);
    let retained = run(&multimodel_spec(), &mix, RETAINED);
    let streamed = run(&multimodel_spec(), &mix, STREAMED);
    assert_bit_identical(&retained, &streamed);
    // the cascade actually escalated (records carry the final model)
    let escalated = retained
        .0
        .records
        .iter()
        .filter(|r| r.model == ModelId::named("llama3-70b"))
        .count();
    assert!(
        escalated > 0 && escalated < retained.0.records.len(),
        "cascade must split the population: {escalated}"
    );
}

#[test]
fn exact_arrival_ties_across_streams_keep_runs_identical() {
    // two classes on identical Uniform clocks force exact arrival-time
    // ties between class streams — the streaming merge and the eager
    // sort must break them identically (by id)
    let a = WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 40, 3.0)
        .with_seed(73)
        .with_arrival(Arrival::Uniform { rate: 3.0 });
    let mix = WorkloadMix::new(vec![(1.0, a.clone()), (1.0, a)]);
    let eager = mix.generate();
    assert!(
        eager.windows(2).any(|w| w[0].arrival == w[1].arrival),
        "setup must produce ties"
    );
    let retained = run(&llm_spec(), &mix, RETAINED);
    let streamed = run(&llm_spec(), &mix, STREAMED);
    assert_bit_identical(&retained, &streamed);
}

// ---- metrics path ----------------------------------------------------------

#[test]
fn record_metrics_match_retained_pool_scan_bit_for_bit() {
    let mix = mixed_mix(80);
    let (coord, _) = run(&mixed_spec(), &mix, RETAINED);
    let slo = SloLadder::retrieval();
    let records = RunMetrics::collect(&coord, &slo);
    let pool_scan = RunMetrics::collect_from_pool(&coord, &slo);
    assert_eq!(records.n_requests, pool_scan.n_requests);
    assert_eq!(records.n_serviced, pool_scan.n_serviced);
    assert_eq!(records.n_failed, pool_scan.n_failed);
    assert_eq!(records.ttft_samples, pool_scan.ttft_samples);
    assert_eq!(records.tpot_samples, pool_scan.tpot_samples);
    assert_eq!(records.e2e_samples, pool_scan.e2e_samples);
    assert_eq!(records.throughput_tok_s, pool_scan.throughput_tok_s);
    assert_eq!(records.goodput_frac, pool_scan.goodput_frac);
    assert_eq!(records.goodput_req_s, pool_scan.goodput_req_s);
    assert_eq!(records.tok_per_joule, pool_scan.tok_per_joule);
    assert_eq!(records.ttft, pool_scan.ttft);
    assert_eq!(records.tpot, pool_scan.tpot);
    assert_eq!(records.e2e, pool_scan.e2e);
}

// ---- memory + determinism --------------------------------------------------

#[test]
fn peak_inflight_equals_pool_peak_under_retirement() {
    let mix = mixed_mix(80);
    let (coord, _) = run(&mixed_spec(), &mix, STREAMED);
    let ops = coord.pool.ops();
    assert_eq!(
        ops.peak_live, coord.stats.peak_inflight,
        "pool occupancy must track in-flight exactly under streaming+retirement"
    );
    assert!(
        ops.peak_live < 80,
        "peak live {} must stay below the 80-request trace",
        ops.peak_live
    );
    assert_eq!(ops.slots, ops.peak_live, "arena allocates only the peak");
    assert_eq!(ops.len, 0, "every request retired by the end");
    assert_eq!(ops.retired as usize, coord.serviced.len() + coord.failed.len());
    assert_eq!(ops.resident, 0);
    // the queue never held the trace either: streaming keeps at most
    // one pending arrival per class outside the queue
    assert!(coord.stats.peak_queue < 80);
}

#[test]
fn freelist_reuse_is_deterministic_across_identical_runs() {
    let observe = || {
        let mix = mixed_mix(60);
        let mut coord = mixed_spec().build().unwrap();
        coord.retire = true;
        coord.stream(&mix);
        // per-event digest of the (id → slot) assignment of every live
        // request: identical runs must recycle identical slots in
        // identical order
        let mut digests = Vec::new();
        while coord.step_event() {
            let mut d = 0u64;
            for (id, _) in &coord.pool {
                let slot = coord.pool.slot_of(*id).unwrap() as u64;
                d = d
                    .wrapping_mul(0x100000001B3)
                    .wrapping_add(id.wrapping_mul(65_521).wrapping_add(slot));
            }
            digests.push(d);
        }
        assert!(coord.all_serviced());
        (coord.serviced.clone(), coord.clock, digests)
    };
    let (s1, c1, d1) = observe();
    let (s2, c2, d2) = observe();
    assert_eq!(s1, s2, "serviced order must be reproducible");
    assert_eq!(c1, c2);
    assert_eq!(d1, d2, "slot assignment must be reproducible event-for-event");
}
