//! Three-layer integration: the AOT-compiled Pallas/JAX predictor loaded
//! through PJRT must agree with the native rust evaluation of the same
//! coefficients (f32-rounding tolerance), and both must track the
//! roofline ground truth the coefficients were fitted on.
//!
//! Requires `make artifacts` (the Makefile runs it before `cargo test`).

use hermes::hardware::models::LLAMA3_70B;
use hermes::hardware::npu::H100;
use hermes::hardware::roofline::LlmCluster;
use hermes::perfmodel::pjrt::PjrtPerfModel;
use hermes::perfmodel::poly::PolyPerfModel;
use hermes::perfmodel::{PerfModel, RooflinePerfModel, StepFeatures};
use hermes::runtime::ArtifactBundle;

const KEY: &str = "llama3-70b@h100/tp8";

fn artifacts_dir() -> std::path::PathBuf {
    ArtifactBundle::default_dir()
}

/// The artifact bundle is produced by `make artifacts` (needs JAX) and
/// PJRT execution needs libxla_extension; neither ships in the repo.
/// When either is missing the parity tests skip instead of failing so
/// the tier-1 suite stays green in offline environments.
fn pjrt_available() -> Option<ArtifactBundle> {
    let bundle = match ArtifactBundle::open(&artifacts_dir()) {
        Ok(b) => b,
        Err(_) => {
            eprintln!("skipping PJRT parity test: no artifact bundle (run `make artifacts`)");
            return None;
        }
    };
    match PjrtPerfModel::load(&artifacts_dir(), KEY) {
        Ok(_) => Some(bundle),
        Err(e) => {
            eprintln!("skipping PJRT parity test: {e:#}");
            None
        }
    }
}

fn feature_grid() -> Vec<StepFeatures> {
    let mut feats = Vec::new();
    // decode-only grid
    for b in [1usize, 4, 16, 64, 256] {
        for ctx in [128.0, 1024.0, 4096.0] {
            feats.push(StepFeatures::decode(b, b as f64 * ctx));
        }
    }
    // prefill-only grid
    for new in [128.0, 512.0, 2048.0, 8192.0] {
        for past in [0.0, 2048.0] {
            feats.push(StepFeatures::prefill(new, past, 2));
        }
    }
    // mixed steps (chunked batching shape)
    for new in [256.0, 512.0] {
        for b in [8usize, 32] {
            feats.push(StepFeatures {
                pf_new: new,
                pf_past: 1024.0,
                pf_items: 1.0,
                dec_batch: b as f64,
                dec_kv: b as f64 * 2048.0,
            });
        }
    }
    // padding / empty row
    feats.push(StepFeatures::default());
    feats
}

#[test]
fn pjrt_matches_native_poly() {
    let Some(bundle) = pjrt_available() else { return };
    let dir = artifacts_dir();
    let mut pjrt = PjrtPerfModel::load(&dir, KEY).unwrap();
    let mut poly = PolyPerfModel::from_coefficients(&bundle.coefficients, KEY).unwrap();

    let feats = feature_grid();
    let a = pjrt.predict_batch(&feats);
    let b = poly.predict_batch(&feats);
    for (i, (pa, pb)) in a.iter().zip(&b).enumerate() {
        for (x, y, head) in [
            (pa.t_prefill, pb.t_prefill, "pf"),
            (pa.t_decode, pb.t_decode, "dec"),
            (pa.t_step, pb.t_step, "step"),
        ] {
            let tol = 1e-5 * y.abs().max(1e-3);
            assert!(
                (x - y).abs() <= tol,
                "row {i} head {head}: pjrt={x} native={y} (feats {:?})",
                feats[i]
            );
        }
    }
}

#[test]
fn pjrt_tracks_roofline_ground_truth() {
    if pjrt_available().is_none() {
        return;
    }
    let dir = artifacts_dir();
    let mut pjrt = PjrtPerfModel::load(&dir, KEY).unwrap();
    let mut roof = RooflinePerfModel::new(LlmCluster::new(LLAMA3_70B, H100, 8));

    // pure decode and pure prefill within the fitted range: <15% error
    let mut feats = Vec::new();
    for b in [1usize, 16, 128] {
        feats.push(StepFeatures::decode(b, b as f64 * 2048.0));
    }
    for new in [256.0, 2048.0, 8192.0] {
        feats.push(StepFeatures::prefill(new, 0.0, 1));
    }
    let pred = pjrt.predict_batch(&feats);
    let truth = roof.predict_batch(&feats);
    for (i, (p, t)) in pred.iter().zip(&truth).enumerate() {
        let rel = (p.t_step - t.t_step).abs() / t.t_step;
        assert!(
            rel < 0.15,
            "row {i}: pred={} truth={} rel={rel} ({:?})",
            p.t_step,
            t.t_step,
            feats[i]
        );
    }
}

#[test]
fn all_manifest_variants_load_and_run() {
    let Some(bundle) = pjrt_available() else { return };
    let dir = artifacts_dir();
    let keys = bundle.variant_keys();
    assert!(keys.len() >= 3, "expected >=3 AOT variants, got {keys:?}");
    for key in keys {
        let mut m = PjrtPerfModel::load(&dir, &key).unwrap();
        let p = m.predict(StepFeatures::decode(8, 8.0 * 1024.0));
        assert!(
            p.t_step > 0.0 && p.t_step < 1.0,
            "{key}: implausible decode step {p:?}"
        );
    }
}

#[test]
fn batches_larger_than_exe_rows_chunk_correctly() {
    if pjrt_available().is_none() {
        return;
    }
    let dir = artifacts_dir();
    let mut pjrt = PjrtPerfModel::load(&dir, KEY).unwrap();
    let rows = pjrt.rows();
    let feats: Vec<StepFeatures> = (0..rows * 2 + 7)
        .map(|i| StepFeatures::decode(1 + i % 32, ((1 + i % 32) * 1024) as f64))
        .collect();
    let out = pjrt.predict_batch(&feats);
    assert_eq!(out.len(), feats.len());
    // same features → same prediction regardless of chunk position
    let single = pjrt.predict(feats[rows + 3]);
    assert_eq!(out[rows + 3], single);
}
