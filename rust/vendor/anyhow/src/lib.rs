//! Offline stand-in for the `anyhow` crate.
//!
//! The HERMES build environment has no network access and no crates.io
//! cache, so this vendored shim provides the (small) subset of anyhow's
//! API the simulator uses: [`Error`], [`Result`], the [`Context`]
//! extension trait for `Result`/`Option`, and the [`anyhow!`]/[`bail!`]
//! macros. Error values carry a context chain; `{e}` prints the
//! outermost context and `{e:#}` prints the whole chain separated by
//! `": "`, matching anyhow's formatting contract closely enough for
//! HERMES's error messages and tests.

use std::fmt;

/// An error with a chain of context messages, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (what `Context::context` does).
    pub fn wrap(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`: that keeps the blanket `From` below coherent with
// core's reflexive `impl From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or turn `None` into an error
/// (`Option`), exactly like anyhow's `Context` trait.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err()).context("reading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: no such file");
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing --config").unwrap_err();
        assert_eq!(format!("{e}"), "missing --config");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros() {
        fn fails(n: usize) -> Result<()> {
            if n > 3 {
                bail!("too many: {n}");
            }
            Err(anyhow!("always {}", n))
        }
        assert_eq!(format!("{}", fails(5).unwrap_err()), "too many: 5");
        assert_eq!(format!("{}", fails(1).unwrap_err()), "always 1");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(format!("{from_string}"), "plain");
    }

    #[test]
    fn with_context_lazy() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 7: no such file");
    }
}
