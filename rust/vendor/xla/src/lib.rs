//! Offline stub of the `xla` (xla_extension 0.5.1) PJRT bindings.
//!
//! The real crate links libxla_extension and executes AOT-compiled
//! Pallas/JAX artifacts on the XLA CPU client. This container image has
//! no XLA shared library, so this stub mirrors the API surface
//! `hermes::runtime` uses and returns a uniform "PJRT runtime
//! unavailable" error from every entry point. The simulator detects the
//! failure at client-construction time and falls back to the analytical
//! roofline predictor (`sim::builder::ServingSpec::build`), so every
//! experiment still runs — only the PJRT parity tests are skipped.
//!
//! Swapping in the real bindings requires no source change elsewhere:
//! point the `xla` path dependency in the workspace `Cargo.toml` at the
//! real crate.

use std::fmt;

/// The single error every stubbed operation returns.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (offline `xla` stub built without libxla_extension)"
    ))
}

/// Stub of the PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub; callers fall back to analytical models.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Stub of a parsed HLO module.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Stub of an XLA computation graph.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Stub of a host literal (dense tensor value).
#[derive(Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

/// Stub of a compiled, loaded executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Stub of a device buffer.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }
}
