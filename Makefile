# HERMES build shortcuts. The Rust side is fully offline; `artifacts`
# needs a Python environment with JAX (see python/compile/).

.PHONY: build test bench doc clippy artifacts

build:
	cargo build --release

test:
	cargo test -q

# paper-figure regenerators at CI scale; HERMES_FULL=1 for paper scale
bench:
	cargo bench

doc:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

clippy:
	cargo clippy --all-targets -- -D warnings

# Fit the step-time regression and AOT-compile the Pallas/JAX predictor
# into artifacts/ (manifest.json, coefficients.json, *.hlo.txt). The
# simulator falls back to the analytical roofline when this has not run.
artifacts:
	python3 python/compile/fit.py
	python3 python/compile/aot.py
