//! Bench: regenerate Table III (batching-strategy recommendation matrix
//! across traces × request types × system sizes × objectives).

use hermes::experiments::table3;
use hermes::util::bench::banner;

fn main() {
    banner("Table III — batching strategy recommendations");
    let fast = std::env::var("HERMES_FULL").is_err();
    let rows = table3::run(fast).expect("table3");
    assert!(rows.len() >= 10, "expected a full matrix, got {}", rows.len());

    // paper headline: disaggregated dominates the throughput/energy
    // column in the (large) majority of cases
    let with_energy: Vec<_> = rows.iter().filter(|r| r.throughput_energy != "-").collect();
    let disagg_wins = with_energy
        .iter()
        .filter(|r| r.throughput_energy.starts_with("disagg"))
        .count();
    assert!(
        disagg_wins * 2 > with_energy.len(),
        "disaggregated should win throughput/energy in most cases ({disagg_wins}/{})",
        with_energy.len()
    );
    println!(
        "\ndisaggregated wins throughput/energy in {disagg_wins}/{} cases (paper: most cases)",
        with_energy.len()
    );
}
