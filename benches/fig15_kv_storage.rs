//! Bench: regenerate Fig 15 (remote KV-cache storage architectures:
//! e2e latency CDFs across tiers A/B/C/C+DCN/recompute, 4K vs 24K
//! caches, private vs shared scenarios).

use hermes::experiments::fig15;
use hermes::util::bench::banner;
use hermes::util::stats;

fn main() {
    banner("Fig 15 — remote KV-cache storage design points");
    let fast = std::env::var("HERMES_FULL").is_err();
    let rows = fig15::run(fast).expect("fig15");
    assert_eq!(rows.len(), 2 * 2 * 5);

    let get = |scenario: &str, tokens: usize, config: &str| {
        rows.iter()
            .find(|r| r.scenario == scenario && r.cache_tokens == tokens && r.config == config)
            .unwrap()
    };

    // paper shape 1: recomputation is competitive for SHORT caches...
    let rec4 = get("private", 4096, "recompute").metrics.e2e.p50;
    let rack4 = get("private", 4096, "C:rack").metrics.e2e.p50;
    assert!(
        rec4 < 2.5 * rack4 + 0.5,
        "recompute should be competitive at 4K: {rec4} vs rack {rack4}"
    );

    // ...and prohibitive vs a hit-serving tier for LONG caches
    let rec24 = get("private", 24576, "recompute").metrics.e2e.p90;
    let plat24 = get("private", 24576, "B:platform").metrics.e2e.p90;
    assert!(
        rec24 > plat24,
        "24K recompute ({rec24}) should lose to platform tier ({plat24})"
    );

    // paper shape 2: platform tier (B) offers the best T90 for private
    // KV (balances hit rate and bandwidth)
    let b = get("private", 24576, "B:platform").metrics.e2e.p90;
    let c = get("private", 24576, "C:rack").metrics.e2e.p90;
    let a = get("private", 24576, "A:dedicated").metrics.e2e.p90;
    assert!(b < c, "private 24K: platform T90 {b} must beat rack {c}");
    assert!(b <= a * 1.05, "private 24K: platform T90 {b} should not lose to dedicated {a}");

    // paper shape 3 (capacity mechanism): a per-client slice of an
    // O(10^10)-token shared corpus barely ever hits — the rack tier's
    // aggregate capacity is what keeps the recompute fallback rare.
    // (The latency crossover additionally needs the 2 GB/s rack links to
    // not be the binding constraint — see EXPERIMENTS.md §Fig15 caveat.)
    let ded_rec = get("shared", 24576, "A:dedicated").metrics.recomputes;
    let rack_rec = get("shared", 24576, "C:rack").metrics.recomputes;
    assert!(
        ded_rec > 4 * rack_rec,
        "shared 24K: dedicated must recompute far more ({ded_rec} vs {rack_rec})"
    );

    // CDF sanity: samples cover the distribution
    for r in &rows {
        let cdf = stats::cdf(&r.metrics.e2e_samples, 20);
        assert_eq!(cdf.len(), 20);
    }
    println!("\nFig 15 shape assertions hold");
}
