//! Bench: regenerate Fig 8 (goodput under multi-path reasoning,
//! Llama3-70B on 8×TP8 clients; panels a=conv/8 branches, b=code/4).

use hermes::experiments::fig8;
use hermes::util::bench::banner;

fn main() {
    banner("Fig 8 — batching strategies under multi-path reasoning");
    let fast = std::env::var("HERMES_FULL").is_err();
    let panels = fig8::run(fast).expect("fig8");
    assert_eq!(panels.len(), 2);
    for p in &panels {
        // every strategy produced sweep points and served requests
        for r in &p.results {
            assert!(!r.points.is_empty());
            assert!(r.points.iter().all(|pt| pt.metrics.n_serviced > 0));
        }
        // reasoning inflates memory: goodput must degrade as rate rises
        for r in &p.results {
            let first = r.points.first().unwrap().metrics.goodput_frac;
            let last = r.points.last().unwrap().metrics.goodput_frac;
            assert!(
                last <= first + 0.35,
                "{} {}: goodput should not improve at saturation ({first} -> {last})",
                p.panel,
                r.label
            );
        }
    }
}
