//! Bench: regenerate Fig 9 (RAG embedding/retrieval placement study).
//! Asserts the paper's three headline shapes.

use hermes::experiments::fig9;
use hermes::util::bench::banner;

fn main() {
    banner("Fig 9 — RAG pipeline bottlenecks across embedding placements");
    let rows = fig9::run(false).expect("fig9");
    assert_eq!(rows.len(), 6);

    let get = |model: &str, hw: &str| {
        rows.iter()
            .find(|r| r.embed_model == model && r.hw == hw)
            .unwrap()
    };

    // 1) big embedder on the small CPU is the bottleneck: embedding
    //    dominates its own TTFT
    let spr = get("mistral-7b", "small-cpu(spr)");
    assert!(spr.embed_s > 0.4 * spr.ttft_s, "embed must dominate TTFT");

    // 2) offloading the embedder to an A100 collapses embed time >10×
    let a100 = get("mistral-7b", "a100+large-cpu");
    assert!(spr.embed_s / a100.embed_s > 10.0);

    // 3) context transfer is <1% of runtime even on PCIe4 ×4
    for r in &rows {
        assert!(r.transfer_pct < 1.0, "{}/{}: transfer {}%", r.embed_model, r.hw, r.transfer_pct);
    }

    // 4) E5-Base never bottlenecks on embedding
    for hw in ["large-cpu(grace)", "small-cpu(spr)", "a100+large-cpu"] {
        let r = get("e5-base", hw);
        assert!(r.embed_s < 0.1 * r.ttft_s);
    }
    println!("\nall Fig 9 shape assertions hold");
}
