//! Core-simulator speed benchmark — the `cargo bench` face of
//! `hermes bench` (docs/performance.md).
//!
//! Runs every `scenarios/bench_*.json` scenario at CI scale by default
//! (`HERMES_FULL=1` for the 50k–200k-request paper scale,
//! `HERMES_JOBS=N` to fan independent runs across N workers), prints
//! wall-clock / events-per-second / peak-pool / pool-op numbers, and
//! writes `BENCH_core.json` so the repo carries a perf trajectory
//! across PRs. Every scenario also runs against the hashmap-pool
//! baseline (pre-arena `RequestPool`) for the arena speedup column;
//! scenarios opting in via `extras.baseline` additionally run under the
//! full-scan routing baseline to report the incremental-load speedup.
//! All of the run/report logic lives in `hermes::bench`, shared with
//! the `hermes bench` subcommand.

use hermes::bench::{self, Baseline};
use hermes::util::bench::banner;

fn main() {
    // mirror the fig* regenerators: fast scale unless HERMES_FULL=1
    let fast = std::env::var("HERMES_FULL").is_err();
    // HERMES_JOBS=N fans the independent runs across N workers (the
    // `hermes bench --jobs N` knob; results are bit-identical to serial)
    let jobs = std::env::var("HERMES_JOBS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1);
    // HERMES_SHARDS=K runs every scenario's shipping config under K
    // conservative time-window domains as well (the `--shards K` knob;
    // default 1 still honors each scenario's own `extras.shards`)
    let shards = std::env::var("HERMES_SHARDS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1);
    let names = bench::bench_scenarios();
    if names.is_empty() {
        eprintln!("no bench_* scenarios found under scenarios/");
        std::process::exit(1);
    }

    banner("core simulator speed (BENCH_core.json)");
    // each scenario's extras.metrics decides its metrics mode (the
    // `hermes bench --metrics auto` default)
    if let Err(e) = bench::run_and_report(
        &names,
        fast,
        Baseline::Auto,
        jobs,
        shards,
        bench::MetricsOverride::Auto,
        "BENCH_core.json",
    ) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
