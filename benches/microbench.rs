//! Microbenchmarks of the simulator hot path (§III-E.1's "20–50×
//! simulation speedup" claim, plus the L3 perf-pass metrics tracked in
//! EXPERIMENTS.md §Perf):
//!   * event-queue throughput (bulk and steady-state push/pop)
//!   * request-pool hot loop: insert, indexed access, and the
//!     insert/retire/reuse cycle behind streaming arrivals + request
//!     retirement — committed baselines for future queue/pool changes
//!   * perf-model backends: roofline vs native poly vs PJRT vs memoized
//!   * end-to-end simulated-seconds-per-wall-second

use hermes::config::slo::SloLadder;
use hermes::coordinator::{Event, EventQueue};
use hermes::hardware::models::LLAMA3_70B;
use hermes::hardware::npu::H100;
use hermes::hardware::roofline::LlmCluster;
use hermes::perfmodel::memo::Memoized;
use hermes::perfmodel::pjrt::PjrtPerfModel;
use hermes::perfmodel::poly::PolyPerfModel;
use hermes::perfmodel::{PerfModel, RooflinePerfModel, StepFeatures};
use hermes::runtime::ArtifactBundle;
use hermes::scheduler::{BatchingKind, RequestPool};
use hermes::sim::builder::{PerfBackend, PoolSpec, ServingSpec};
use hermes::sim::{driver, SimTime};
use hermes::util::bench::{banner, black_box, time_fn};
use hermes::workload::request::{Request, Stage};
use hermes::workload::trace::{TraceKind, WorkloadSpec};

const KEY: &str = "llama3-70b@h100/tp8";

fn bench_event_queue() {
    banner("event queue");
    time_fn("push+pop 100k events", 1, 10, || {
        let mut q = EventQueue::new();
        for i in 0..100_000u64 {
            q.push(
                SimTime::from_nanos(i * 977 % 1_000_000),
                Event::EngineStep { client: (i % 64) as usize },
            );
        }
        while let Some(e) = q.pop() {
            black_box(e);
        }
    });
    // the event loop's actual access pattern: a small queue cycling
    // push/pop in steady state (streaming arrivals keep it this small)
    time_fn("steady-state push/pop, 256-deep, 100k cycles", 1, 10, || {
        let mut q = EventQueue::new();
        for i in 0..256u64 {
            q.push(SimTime::from_nanos(i * 977), Event::EngineStep { client: 0 });
        }
        for i in 0..100_000u64 {
            let (t, e) = q.pop().unwrap();
            black_box(e);
            q.push(
                t + SimTime::from_nanos(1 + i % 997),
                Event::EngineStep {
                    client: (i % 64) as usize,
                },
            );
        }
    });
}

fn pool_request(id: u64) -> Request {
    Request::new(
        id,
        "llama3-70b",
        SimTime::ZERO,
        vec![Stage::Prefill, Stage::Decode],
        1024,
        128,
    )
}

/// Commit a baseline for the pool hot loop: raw insert throughput, the
/// get/get_mut access path, and the streaming+retirement steady state
/// (insert + retire through the freelist with a bounded live window).
fn bench_request_pool() {
    banner("request pool (arena)");
    time_fn("insert 100k (no retirement)", 1, 10, || {
        let mut pool = RequestPool::new();
        for id in 0..100_000u64 {
            pool.insert(id, pool_request(id));
        }
        black_box(pool.ops());
    });
    let mut pool = RequestPool::new();
    for id in 0..100_000u64 {
        pool.insert(id, pool_request(id));
    }
    time_fn("1M random-ish get/get_mut over 100k ids", 1, 10, || {
        let mut acc = 0usize;
        for i in 0..1_000_000u64 {
            let id = (i * 48_271) % 100_000;
            acc += pool[&id].prompt_tokens;
            pool.get_mut(&id).unwrap().decoded = (i % 7) as usize;
        }
        black_box(acc);
    });
    time_fn("insert+retire+reuse, 1k live window, 100k ids", 1, 10, || {
        let mut pool = RequestPool::new();
        for id in 0..100_000u64 {
            pool.insert(id, pool_request(id));
            if id >= 1000 {
                pool.remove(id - 1000);
            }
        }
        let ops = pool.ops();
        assert!(ops.slots <= 1001 + 1, "freelist must bound slots: {}", ops.slots);
        black_box(ops);
    });
}

fn decode_grid(n: usize) -> Vec<StepFeatures> {
    (0..n)
        .map(|i| StepFeatures::decode(1 + i % 64, ((1 + i % 64) * (512 + i % 2048)) as f64))
        .collect()
}

fn bench_perf_models() {
    banner("perf-model backends (1024 candidate step plans)");
    let feats = decode_grid(1024);
    let cluster = LlmCluster::new(LLAMA3_70B, H100, 8);

    let mut roofline = RooflinePerfModel::new(cluster);
    let t_roof = time_fn("roofline (analytical)", 2, 20, || {
        black_box(roofline.predict_batch(&feats));
    });

    let dir = ArtifactBundle::default_dir();
    let bundle = ArtifactBundle::open(&dir).expect("run `make artifacts`");
    let mut poly = PolyPerfModel::from_coefficients(&bundle.coefficients, KEY).unwrap();
    let t_poly = time_fn("native poly (fitted)", 2, 20, || {
        black_box(poly.predict_batch(&feats));
    });

    let mut pjrt = PjrtPerfModel::load(&dir, KEY).unwrap();
    let t_pjrt = time_fn("pjrt (AOT pallas/XLA)", 2, 20, || {
        black_box(pjrt.predict_batch(&feats));
    });

    let mut memo = Memoized::new(PjrtPerfModel::load(&dir, KEY).unwrap());
    memo.predict_batch(&feats); // warm the cache
    let t_memo = time_fn("pjrt+memo (warm)", 2, 20, || {
        black_box(memo.predict_batch(&feats));
    });

    println!(
        "\nspeedup of fitted-poly over analytical: {:.1}x (paper: 20-50x for ML vs analytical sim)",
        t_roof.mean / t_poly.mean
    );
    println!(
        "pjrt overhead vs native poly: {:.1}x; memoized recovers to {:.1}x of poly",
        t_pjrt.mean / t_poly.mean,
        t_memo.mean / t_poly.mean
    );
    println!("memo hit rate: {:.1}%", memo.hit_rate() * 100.0);
}

fn bench_end_to_end() {
    banner("end-to-end simulation rate");
    let slo = SloLadder::standard();
    for (name, perf) in [
        ("roofline", PerfBackend::Roofline),
        ("poly", PerfBackend::Poly),
        ("pjrt-memo", PerfBackend::PjrtMemo),
    ] {
        let spec = ServingSpec::new(
            "llama3-70b",
            H100,
            8,
            PoolSpec::Combined { kind: BatchingKind::Continuous, n: 4 },
        )
        .with_perf(perf);
        let workload =
            WorkloadSpec::new("llama3-70b", TraceKind::AzureConv, 200, 8.0).with_seed(1);
        let mut sim_seconds = 0.0;
        let s = time_fn(&format!("serve 200 conv requests [{name}]"), 1, 5, || {
            let m = driver::run(&spec, &workload, &slo).unwrap();
            sim_seconds = m.makespan;
            black_box(m);
        });
        println!(
            "    -> simulates {:.0}x faster than real time ({:.1} sim-s / {:.3} wall-s)",
            sim_seconds / s.mean,
            sim_seconds,
            s.mean
        );
    }
}

fn main() {
    bench_event_queue();
    bench_request_pool();
    bench_perf_models();
    bench_end_to_end();
}
