//! Bench: regenerate Fig 5 (validation vs splitwise-sim-like baseline).
//! Full scale with HERMES_FULL=1; CI scale otherwise.

use hermes::experiments::fig5;
use hermes::util::bench::{banner, time_fn};

fn main() {
    banner("Fig 5 — HERMES vs splitwise-sim-like baseline (80-GPU disaggregated)");
    let fast = std::env::var("HERMES_FULL").is_err();
    let rows = fig5::run(fast).expect("fig5");
    assert!(!rows.is_empty());
    // shape check: the two simulators agree within the paper's 6% band
    for r in &rows {
        assert!(
            r.gap_pct < 6.0,
            "{} rps {}: gap {:.2}% exceeds the paper's 6% band",
            r.model,
            r.rps,
            r.gap_pct
        );
    }
    time_fn("fig5 single validation run", 0, 3, || {
        fig5::run(true).unwrap();
    });
}
