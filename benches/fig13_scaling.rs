//! Bench: regenerate Fig 13 (goodput vs generation SLA while scaling
//! serving clients; 99%-compliance criterion).

use hermes::experiments::fig13;
use hermes::util::bench::banner;

fn main() {
    banner("Fig 13 — goodput vs generation SLA, scaling clients");
    let fast = std::env::var("HERMES_FULL").is_err();
    let rows = fig13::run(fast).expect("fig13");
    assert!(!rows.is_empty());
    // per (strategy, clients): tightening the SLA can only reduce the
    // sustainable rate
    for r in &rows {
        let same: Vec<&fig13::Fig13Row> = rows
            .iter()
            .filter(|x| x.strategy == r.strategy && x.clients == r.clients)
            .collect();
        for w in same.windows(2) {
            assert!(
                w[1].sla_mult <= w[0].sla_mult,
                "rows must be ordered tightening"
            );
            assert!(
                w[1].max_rate <= w[0].max_rate + 1e-9,
                "{} n={}: tighter SLA cannot raise sustainable rate",
                r.strategy,
                r.clients
            );
        }
    }
    println!("\nFig 13 monotonicity assertions hold");
}
