//! Bench: regenerate Fig 11 (batching strategies with a RAG stage:
//! +3K retrieval tokens, retrieval SLO ladder).

use hermes::experiments::{fig10, fig11};
use hermes::util::bench::banner;

fn main() {
    banner("Fig 11 — batching strategies with RAG pipelines");
    let fast = std::env::var("HERMES_FULL").is_err();
    let rag = fig11::run(fast).expect("fig11");
    assert_eq!(rag.len(), 2);

    // paper shape: the RAG stage lowers the sustainable injection rate
    // relative to the regular pipeline (longer prefills)
    let plain = fig10::run(fast).expect("fig10");
    for (r, p) in rag.iter().zip(&plain) {
        let best_rate = |panels: &[hermes::experiments::common::StrategyResult]| {
            panels
                .iter()
                .filter_map(|s| s.best().map(|pt| pt.rate))
                .fold(0.0f64, f64::max)
        };
        let rag_rate = best_rate(&r.results);
        let plain_rate = best_rate(&p.results);
        if rag_rate > 0.0 && plain_rate > 0.0 {
            assert!(
                rag_rate <= plain_rate + 1e-9,
                "{}: RAG pipeline should not sustain more than regular ({rag_rate} vs {plain_rate})",
                r.panel
            );
        }
    }
    println!("\nFig 11 shape assertions hold (RAG lowers sustainable rate)");
}
