//! Bench: regenerate Fig 6 (end-to-end fidelity of the fitted predictor
//! vs the fine-grained oracle over the chunked-batching sweep grid).

use hermes::experiments::fig6;
use hermes::util::bench::banner;
use hermes::util::stats;

fn main() {
    banner("Fig 6 — ML-predictor end-to-end fidelity (Llama3-70B, HGX-H100)");
    let fast = std::env::var("HERMES_FULL").is_err();
    let rows = fig6::run(fast).expect("fig6");
    let errs: Vec<f64> = rows.iter().map(|r| r.err_pct).collect();
    let avg = stats::mean(&errs);
    // paper: <2% average end-to-end error
    assert!(avg < 2.0, "average fidelity error {avg:.2}% exceeds 2%");
}
