//! Bench: regenerate Fig 12 (batching strategies with KV-cache
//! retrieval: 3K cached context tokens, no recompute).

use hermes::experiments::fig12;
use hermes::util::bench::banner;

fn main() {
    banner("Fig 12 — batching strategies with KV-retrieval pipelines");
    let fast = std::env::var("HERMES_FULL").is_err();
    let panels = fig12::run(fast).expect("fig12");
    assert_eq!(panels.len(), 2);
    for p in &panels {
        for r in &p.results {
            for pt in &r.points {
                assert!(pt.metrics.n_serviced > 0, "{}: no serviced requests", r.label);
                // cached context attends over ≥3K extra tokens → TPOT must
                // still be bounded (retrieval does not extend generation)
                assert!(pt.metrics.tpot.p50 < 0.2, "{}: runaway TPOT", r.label);
            }
        }
    }
    println!("\nFig 12 shape assertions hold");
}
