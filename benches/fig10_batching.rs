//! Bench: regenerate Fig 10 (batching strategies, regular prefill-decode
//! pipelines, code + conversation traces).

use hermes::experiments::fig10;
use hermes::util::bench::banner;

fn main() {
    banner("Fig 10 — batching strategies on regular pipelines (a: code, b: conv)");
    let fast = std::env::var("HERMES_FULL").is_err();
    let panels = fig10::run(fast).expect("fig10");
    assert_eq!(panels.len(), 2);
    for p in &panels {
        // paper shape: disaggregated wins throughput/energy
        if let (_, _, Some(energy_winner)) = &p.winners {
            assert!(
                energy_winner.starts_with("disagg"),
                "{}: throughput/energy winner should be disaggregated, got {energy_winner}",
                p.panel
            );
        }
        // every strategy produced at least one SLO-satisfying point
        for r in &p.results {
            assert!(!r.points.is_empty(), "{}: no sweep points", r.label);
        }
    }
    println!("\nFig 10 shape assertions hold (disaggregated wins throughput/energy)");
}
